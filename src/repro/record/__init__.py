"""repro.record — the CODY distributed recording session.

Models the paper's two-party record phase: a ``DeviceProxy`` (GPU
hardware: executes committed op batches, holds readbacks, mirrors synced
state) and a ``CloudDryrun`` (GPU software: JAX lower/compile stack +
register-access interaction plan) collaborate through a
``RecordingSession`` over a ``NetworkEmulator``, with the paper's three
record-time optimizations — deferral (§4.1+4.3), speculation (§4.2),
metastate-only sync (§5) — composed as stackable interceptor passes.
"""
from repro.record.cloud import REPLAY_CONSUMED_SITES, CloudDryrun
from repro.record.device import DeviceProxy, FlakyRegisterDevice
from repro.record.fanout import (DeviceSlot, RecordCampaign,
                                 SpeculationHistoryStore, VariantSpec)
from repro.record.session import (PASS_NAMES, DeferralPass, MetasyncPass,
                                  RecordingSession, SessionReusedError,
                                  SpeculationPass, WireLink, resolve_passes)

__all__ = [
    "CloudDryrun", "DeviceProxy", "FlakyRegisterDevice", "RecordingSession",
    "SessionReusedError", "DeferralPass", "SpeculationPass", "MetasyncPass",
    "WireLink", "PASS_NAMES", "resolve_passes", "REPLAY_CONSUMED_SITES",
    "RecordCampaign", "DeviceSlot", "SpeculationHistoryStore", "VariantSpec",
]
