"""DeviceProxy — the "hardware" half of the CODY recording session.

The paper's record phase is two-party: the mobile device owns the GPU
*hardware*, the cloud dryrun service owns the GPU *software* stack.  The
DeviceProxy models the device side of that split: it executes committed
register-access batches in program order (it IS the ``CommitQueue``
channel), holds the readback values the driver observes, and mirrors the
state the cloud syncs down after each GPU job — either a full memory image
(naive) or a metastate-only compressed delta (paper §5).

Register semantics mirror the paper's Mali trace classes:

  * ordinary registers read back a stable per-site value (speculatable —
    the paper's "constant across jobs" class);
  * ``latest_flush_id`` advances on every read (the paper's documented
    non-speculatable register: history never converges, so the speculator
    correctly falls back to a blocking commit for it);
  * polls execute device-side and return the loop trip count (§4.3).

``snapshot()/restore()`` are the metastate-only checkpoints speculation
rolls back to on a mispredict (§4.2 / §7.3).
"""
from __future__ import annotations

import collections
import zlib
from typing import Any, Dict

from repro.core.metasync import DeltaSync

POLL_TRIPS = 3


def stable_register_value(site: str) -> int:
    """Deterministic per-register readback (hash() is process-salted)."""
    return zlib.crc32(site.encode()) % 997


class DeviceProxy:
    """Executes the device side of a recording session."""

    def __init__(self):
        self.regs: Dict[str, Any] = {}
        self.flush_id = 0
        self.exec_log = []                 # (kind, site) in committed order
        self.meta_mirror: Dict[str, Any] = {}   # metastate-delta syncs (§5)
        self.state_mirror = None                # full-image syncs (naive)
        self.jobs_synced = 0
        self.stats = collections.Counter()

    # ------------------------------------------------------- op execution --
    def channel(self, op) -> Any:
        """In-order executor for one committed ``deferral.Op``."""
        self.exec_log.append((op.kind, op.site))
        self.stats["ops"] += 1
        if op.kind == "write":
            self.regs[op.site] = op.payload
            return None
        if op.kind == "poll":
            self.stats["polls_offloaded"] += 1
            return POLL_TRIPS
        return self.read_value(op.site)

    def read_value(self, site: str) -> Any:
        if site in self.regs:
            return self.regs[site]
        if site.endswith("latest_flush_id"):
            self.flush_id += 1             # nondeterministic register class
            return self.flush_id
        return stable_register_value(site)

    # ----------------------------------------------- speculation rollback --
    def snapshot(self):
        """Metastate-only checkpoint (cheap — regs + counters, never
        program data): what speculation restores on a mispredict."""
        return (dict(self.regs), self.flush_id)

    def restore(self, snap) -> None:
        regs, flush_id = snap
        self.regs = dict(regs)
        self.flush_id = flush_id
        self.stats["rollbacks"] += 1

    # --------------------------------------------------------- state sync --
    def apply_full_sync(self, state) -> None:
        """Naive MemSync: the cloud ships the whole memory image."""
        self.state_mirror = state
        self.jobs_synced += 1
        self.stats["full_syncs"] += 1

    def apply_meta_sync(self, wire: bytes) -> None:
        """Metastate-only delta sync: unpack against the mirrored base —
        the device-side half of ``metasync.DeltaSync`` (§5)."""
        self.meta_mirror = DeltaSync.unpack(wire, self.meta_mirror)
        self.jobs_synced += 1
        self.stats["meta_syncs"] += 1


class FlakyRegisterDevice(DeviceProxy):
    """Test double: one register returns ``value_a`` for the first
    ``flip_after`` reads, then ``value_b`` — builds a predictable history
    and then breaks it, forcing a speculation mispredict + rollback."""

    def __init__(self, site: str, flip_after: int,
                 value_a: int = 1, value_b: int = 2):
        super().__init__()
        self._site = site
        self._flip_after = flip_after
        self._values = (value_a, value_b)
        self._reads = 0

    def read_value(self, site: str) -> Any:
        if site == self._site:
            self._reads += 1
            return self._values[self._reads > self._flip_after]
        return super().read_value(site)


__all__ = ["DeviceProxy", "FlakyRegisterDevice", "POLL_TRIPS",
           "stable_register_value"]
