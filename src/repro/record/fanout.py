"""RecordCampaign — multi-device record fan-out on a virtual tick clock.

The paper's recording environment drives ONE mobile device against the
cloud dry-run per session, so populating the registry with a new key's
shape variants (prefill buckets x decode x kinds) is serial: campaign
time scales linearly with variant count even after CODY's 92% per-record
cut.  A ``RecordCampaign`` makes the *fleet* record: a work-queue of
variants fans out across N ``DeviceSlot``s, each device running its own
``RecordingSession`` over its own ``NetworkEmulator`` span, scheduled on
the same deterministic virtual tick clock as ``fleet.ReplicaPool`` — no
wall clock, no ``random``, identical results every run.

Three perf levers, all measured by ``benchmarks/fanout_bench.py``:

  * **Shared speculation history** (``SpeculationHistoryStore``): one
    ``HistorySpeculator`` per hardware class, injected into every
    session of that class, so device A's validated commits warm device
    B's predictions — later variants skip the history-k warm-up that a
    cold-per-session speculator pays per record.
  * **Artifact sharing**: each variant is compiled ONCE
    (``Workload.compile``) and every session replays that artifact
    (``RecordingSession.finalize``) — devices never recompile, and the
    recordings stay byte-identical to their serial counterparts
    (serialized executables are not byte-deterministic across
    recompiles, so sharing is what makes ``bit_exact_vs_serial`` hold).
  * **Multi-variant lease fan-out** (``RegistryService.variant_lease``):
    concurrent missers of *different* variants become workers instead of
    waiters on one single-flight lease; each finished variant publishes
    incrementally through the service's per-key DeltaSync.

Scheduling invariant: variants are claimed FIFO, so the *execution*
order (which is what warms the shared speculator) equals the queue order
at EVERY device count — per-variant durations are identical across the
1/2/4/8-device ladder and the makespan shrinkage is purely virtual-time
concurrency.  That is what makes the ladder strictly monotone by
construction rather than by luck.
"""
from __future__ import annotations

import collections
import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.netem import NetworkEmulator
from repro.core.recording import Recording
from repro.core.speculation import HistorySpeculator
from repro.obs.trace import NULL, traced
from repro.record.cloud import CloudDryrun
from repro.record.device import DeviceProxy
from repro.record.session import RecordingSession

_EPS = 1e-9

# HistorySpeculator.stats key -> the metric/stat name campaigns expose
_SPEC_STAT_KEYS = (("predicts", "predict"), ("predicted", "hit"),
                   ("records", "record"))


class VariantSpec:
    """One unit of campaign work: a registry key plus a zero-arg compile
    producing its artifact (``Workspace.campaign`` builds these from
    ``Workload.compile``; anything with the same shape works)."""

    __slots__ = ("key", "compile_fn", "label")

    def __init__(self, key: str, compile_fn: Callable[[], Recording],
                 label: Optional[str] = None):
        self.key = key
        self.compile_fn = compile_fn
        self.label = label if label else key

    def __repr__(self):
        return f"VariantSpec({self.label!r})"


class DeviceSlot:
    """One recording device in the pool: a netem billing span (its own
    ``checkpoint()/delta()`` spans per session — never aliased with its
    siblings') plus fan-out bookkeeping."""

    def __init__(self, name: str, netem: Optional[NetworkEmulator], *,
                 hw_class: str = "edge-gpu"):
        self.name = name
        self.netem = netem
        self.hw_class = hw_class
        self.busy_until = 0.0
        self.recorded = 0
        self.busy_virtual_s = 0.0
        self.stats = collections.Counter()

    def snapshot(self) -> dict:
        return {
            "name": self.name,
            "hw_class": self.hw_class,
            "net": self.netem.profile.name if self.netem is not None
            else "in-process",
            "recorded": self.recorded,
            "busy_virtual_s": round(self.busy_virtual_s, 6),
            "blocking_round_trips": int(self.stats["blocking_rts"]),
            "spec": {stat: int(self.stats[f"spec_{stat}"])
                     for _raw, stat in _SPEC_STAT_KEYS},
        }


class SpeculationHistoryStore:
    """Per-hardware-class ``HistorySpeculator`` pool.

    Devices of one hardware class expose the same register behavior, so
    their commit histories are interchangeable: ONE speculator per class,
    shared by every session the campaign runs on that class.  Distinct
    classes never mix (a different device generation may legitimately
    return different register values at the same site)."""

    def __init__(self, k: int = 3):
        self.k = k
        self._by_class: Dict[str, HistorySpeculator] = {}

    def speculator(self, hw_class: str) -> HistorySpeculator:
        if hw_class not in self._by_class:
            self._by_class[hw_class] = HistorySpeculator(k=self.k)
        return self._by_class[hw_class]

    def snapshot(self) -> dict:
        return {hw: {"sites": len(s.history),
                     "predicts": int(s.stats["predicts"]),
                     "hits": int(s.stats["predicted"]),
                     "records": int(s.stats["records"]),
                     "hit_rate": round(s.hit_rate(), 6)}
                for hw, s in sorted(self._by_class.items())}


class _CampaignClock:
    """Mutable virtual-time shim for ``Tracer.clock_scope`` — the
    campaign stamps its spans on the tick clock, not any one device's
    emulator."""

    __slots__ = ("virtual_time_s",)

    def __init__(self):
        self.virtual_time_s = 0.0


class RecordCampaign:
    """Fan a variant work-queue out across a device pool.

    ``run()`` executes every claimable variant exactly once and returns
    ``{key: Recording}``.  With a ``service``, variants are claimed
    through a multi-variant lease set (published or foreign-leased keys
    are skipped, not re-recorded) and each finished variant is published
    incrementally.  ``share_history=False`` is the cold baseline: every
    session gets a fresh speculator, exactly today's serial
    ``Workload.record`` behavior."""

    def __init__(self, variants: Sequence[VariantSpec],
                 devices: Sequence[DeviceSlot], *,
                 share_history: bool = True, spec_k: int = 3,
                 artifacts: Optional[Dict[str, Recording]] = None,
                 passes="all", jobs: Optional[int] = None,
                 tick_s: float = 0.02, name: str = "campaign",
                 tracer=NULL, metrics=None, service=None,
                 max_ticks: int = 500_000):
        if not devices:
            raise ValueError("RecordCampaign needs at least one device")
        self.variants = list(variants)
        self.devices = list(devices)
        self.share_history = share_history
        self.history = SpeculationHistoryStore(k=spec_k)
        self.artifacts = artifacts if artifacts is not None else {}
        self.passes = passes
        self.jobs = jobs
        self.tick_s = tick_s
        self.name = name
        self.tracer = tracer if tracer is not None else NULL
        self.metrics = metrics
        self.service = service
        self.max_ticks = max_ticks
        self.ticks = 0
        self.clock = 0.0
        self.counters = collections.Counter()
        self.recordings: Dict[str, Recording] = {}
        self.sessions: List[tuple] = []       # (key, session report)
        self._clk = _CampaignClock()
        self._ran = False

    # ------------------------------------------------------------ artifacts --
    def _artifact(self, v: VariantSpec) -> Recording:
        """Compile-once artifact sharing: the dict may be pre-seeded (a
        bench sharing one compile across ladder rungs) and is filled on
        first use otherwise."""
        if v.key not in self.artifacts:
            with traced(self.tracer, "campaign.compile", "campaign",
                        variant=v.label):
                self.artifacts[v.key] = v.compile_fn()
            self.counters["compiles"] += 1
        else:
            self.counters["artifact_reuses"] += 1
        return self.artifacts[v.key]

    # ------------------------------------------------------------- sessions --
    def _execute(self, slot: DeviceSlot, v: VariantSpec):
        """Run ONE fresh single-use session for (device, variant); returns
        (recording, report, virtual duration).  The session's netem spans
        bill into the device's own emulator via checkpoint()/delta()."""
        art = self._artifact(v)
        spec = self.history.speculator(slot.hw_class) \
            if self.share_history else HistorySpeculator(k=self.history.k)
        before = dict(spec.stats)
        cloud = CloudDryrun(jobs=self.jobs) if self.jobs is not None \
            else CloudDryrun()
        session = RecordingSession(
            device=DeviceProxy(), cloud=cloud, netem=slot.netem,
            passes=self.passes, tracer=self.tracer, speculator=spec)
        rec = session.finalize(
            Recording(dict(art.manifest), art.payload, art.trees))
        rep = session.report()
        self.sessions.append((v.key, rep))
        dur = float(rep["virtual_time_s"])
        self._bill(slot, spec, before, rep, dur)
        return rec, rep, dur

    def _bill(self, slot: DeviceSlot, spec: HistorySpeculator,
              before: dict, rep: dict, dur: float) -> None:
        """Per-(hw_class, device) speculation counters from the
        speculator's OWN stats delta — the shared-history lift is
        measured, not inferred from round trips."""
        slot.recorded += 1
        slot.busy_virtual_s += dur
        slot.stats["blocking_rts"] += rep["blocking_round_trips"]
        deltas = {}
        for raw, stat in _SPEC_STAT_KEYS:
            d = int(spec.stats.get(raw, 0)) - int(before.get(raw, 0))
            deltas[stat] = d
            slot.stats[f"spec_{stat}"] += d
            self.counters[f"spec_{stat}"] += d
        if self.metrics is not None:
            for stat, d in deltas.items():
                if d:
                    self.metrics.counter(
                        f"spec_history_{stat}", hw_class=slot.hw_class,
                        device=slot.name).inc(d)
            self.metrics.histogram("fanout_record_s", campaign=self.name,
                                   device=slot.name).observe(dur)
            self.metrics.counter("fanout_variants_recorded",
                                 campaign=self.name).inc()

    # ----------------------------------------------------------------- run --
    def run(self) -> Dict[str, Recording]:
        if self._ran:
            raise RuntimeError(f"campaign '{self.name}' already ran; "
                               "build a new RecordCampaign per run")
        self._ran = True
        lease_set = None
        queue: List[VariantSpec] = []
        if self.service is not None:
            lease_set = self.service.variant_lease(
                self.name, [v.key for v in self.variants])
            for v in self.variants:
                why = lease_set.claim(v.key)
                if why is None:
                    queue.append(v)
                else:
                    self.counters[f"skipped_{why}"] += 1
        else:
            queue = list(self.variants)
        self.counters["claimed"] = len(queue)

        running: List[tuple] = []   # (finish_t, seq, slot, variant, rec)
        seq = 0
        try:
            with self.tracer.clock_scope(self._clk), \
                    traced(self.tracer, "campaign.run", "campaign",
                           campaign=self.name, devices=len(self.devices),
                           variants=len(queue)):
                while queue or running:
                    for slot in self.devices:
                        if not queue:
                            break
                        if slot.busy_until > self.clock + _EPS:
                            continue
                        v = queue.pop(0)
                        start = self.clock
                        self._clk.virtual_time_s = start
                        if self.tracer:
                            self.tracer.instant("campaign.assign",
                                                "campaign", device=slot.name,
                                                variant=v.label)
                        with traced(self.tracer, "campaign.record",
                                    "campaign", device=slot.name,
                                    variant=v.label):
                            rec, _rep, dur = self._execute(slot, v)
                            self._clk.virtual_time_s = start + dur
                        slot.busy_until = start + dur
                        seq += 1
                        running.append((slot.busy_until, seq, slot, v, rec))
                    if not running:
                        if queue:       # every device idle yet none claimed
                            raise RuntimeError(
                                f"campaign '{self.name}' stuck with "
                                f"{len(queue)} variants unassigned")
                        break
                    target = min(r[0] for r in running)
                    n = max(1, math.ceil(
                        (target - self.clock) / self.tick_s - _EPS))
                    self.ticks += n
                    if self.ticks > self.max_ticks:
                        raise RuntimeError(
                            f"campaign '{self.name}' exceeded max_ticks="
                            f"{self.max_ticks}")
                    self.clock = self.ticks * self.tick_s
                    done = sorted(r for r in running
                                  if r[0] <= self.clock + _EPS)
                    running = [r for r in running
                               if r[0] > self.clock + _EPS]
                    self._clk.virtual_time_s = self.clock
                    for _ft, _seq, slot, v, rec in done:
                        self._complete(lease_set, slot, v, rec)
        except BaseException:
            # release EVERY still-held lease — including the in-flight
            # variant that raised (popped from the queue but never added
            # to ``running``) — or later missers would block forever
            if lease_set is not None:
                for key in list(lease_set.outstanding()):
                    lease_set.fail(key)
            raise
        return self.recordings

    def _complete(self, lease_set, slot: DeviceSlot, v: VariantSpec,
                  rec: Recording) -> None:
        self.recordings[v.key] = rec
        self.counters["recorded"] += 1
        if self.tracer:
            self.tracer.instant("campaign.done", "campaign",
                                device=slot.name, variant=v.label)
        if lease_set is not None:
            # incremental publish: this variant ships (delta-packed by the
            # service's per-key DeltaSync) the moment it finishes — missers
            # waiting on ITS lease unblock without waiting for the campaign
            lease_set.complete(v.key, rec)
            self.counters["publishes"] += 1

    # ------------------------------------------------------------ reporting --
    def hit_rate(self) -> float:
        n = self.counters["spec_predict"]
        return (self.counters["spec_hit"] / n) if n else 0.0

    def stats(self) -> dict:
        """Campaign accounting; shape pinned by
        ``repro.obs.schema.check_campaign_stats``."""
        serial_s = sum(s.busy_virtual_s for s in self.devices)
        return {
            "name": self.name,
            "devices": len(self.devices),
            "variants": len(self.variants),
            "recorded": int(self.counters["recorded"]),
            "skipped_published": int(self.counters["skipped_published"]),
            "skipped_leased": int(self.counters["skipped_leased"]),
            "share_history": self.share_history,
            "tick_s": self.tick_s,
            "ticks": self.ticks,
            "virtual_time_s": round(self.clock, 9),
            "sum_record_virtual_s": round(serial_s, 6),
            "publishes": int(self.counters["publishes"]),
            "compiles": int(self.counters["compiles"]),
            "artifact_reuses": int(self.counters["artifact_reuses"]),
            "speculation": {
                "predicts": int(self.counters["spec_predict"]),
                "hits": int(self.counters["spec_hit"]),
                "records": int(self.counters["spec_record"]),
                "hit_rate": round(self.hit_rate(), 6),
                "shared": self.history.snapshot(),
            },
            "per_device": [s.snapshot() for s in self.devices],
        }


__all__ = ["RecordCampaign", "DeviceSlot", "SpeculationHistoryStore",
           "VariantSpec"]
