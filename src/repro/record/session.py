"""RecordingSession — the CODY two-party record protocol over an emulated
link, with the paper's three optimizations as stackable interceptor passes.

Layering (outer → inner)::

    CloudDryrun ──► [MetasyncPass] ─► [DeferralPass] ─► [SpeculationPass] ─► WireLink ──► DeviceProxy
      (software)      sync deltas       batch commits      async commits      CommitQueue     (hardware)
                         §5                §4.1+4.3            §4.2          + NetworkEmulator

The cloud emits the interaction plan; each enabled pass intercepts the
part of the wire protocol it optimizes; ``WireLink`` is the naive base
transport (one blocking round trip per register access, full memory image
per job sync).  Any subset of passes composes — the session always stacks
them in canonical order — which is exactly what the paper's naive →
+deferral → +speculation → +metasync ablation (Fig. 7 / Table 1) needs.

Per-pass accounting uses ``NetworkEmulator.checkpoint()/delta()`` spans,
so each pass reports the blocking/async round trips and bytes that flowed
through *it* without clobbering the emulator's global totals.

``RecordingSession.local()`` is the in-process degenerate session: device
and cloud co-located, all passes on, no emulator — ``core.recorder.record``
routes through it, producing the same artifact as ``compile_artifact``
plus zeroed session fields in the manifest.
"""
from __future__ import annotations

import collections
import traceback
from typing import Optional, Sequence, Tuple, Union

from repro.core.deferral import CommitQueue
from repro.core.metasync import DeltaSync, full_pack, split
from repro.core.netem import NetProfile, NetworkEmulator
from repro.core.recording import Recording
from repro.core.speculation import (HistorySpeculator, MispredictError,
                                    SpeculativeRunner)
from repro.obs.trace import NULL, traced
from repro.record.cloud import CloudDryrun
from repro.record.device import POLL_TRIPS, DeviceProxy

PASS_NAMES = ("deferral", "speculation", "metasync")


class SessionReusedError(RuntimeError):
    """A ``RecordingSession`` was exercised twice.

    Sessions are single-use — device state, speculation history,
    delta-sync bases and per-pass accounting all belong to ONE recording.
    The message names the call site that consumed the session first, so a
    fan-out scheduler handing sessions around can find the offender."""

    def __init__(self, first_use_site: str):
        super().__init__(
            "RecordingSession is single-use: build a new session per "
            "recording (its device state, speculation history and "
            "accounting belong to one record); this session was first "
            f"used at {first_use_site}")
        self.first_use_site = first_use_site


def _caller_site() -> str:
    """Deepest stack frame outside this module — where exercise() was
    entered from."""
    here = __file__
    for frame in reversed(traceback.extract_stack()):
        if frame.filename != here:
            return f"{frame.filename}:{frame.lineno} (in {frame.name})"
    return "<unknown>"


def resolve_passes(passes: Union[str, Sequence[str], None]) \
        -> Tuple[str, ...]:
    """Normalize a pass spec — "all", "none", comma string, or sequence —
    into the canonical composition order (subset of ``PASS_NAMES``)."""
    if passes is None or passes == "all":
        return PASS_NAMES
    if passes == "none" or passes == "naive":
        return ()
    if isinstance(passes, str):
        passes = [p for p in passes.split(",") if p.strip()]
    names = {p.strip() for p in passes}
    unknown = names - set(PASS_NAMES)
    if unknown:
        raise ValueError(f"unknown session passes {sorted(unknown)}; "
                         f"valid: {PASS_NAMES}")
    return tuple(p for p in PASS_NAMES if p in names)


class LinkLayer:
    """One interceptor in the session's wire-protocol stack.

    Calls enter at the outermost layer; the default implementation
    delegates inward.  Cross-cutting re-entry (e.g. deferral deciding a
    batch must ship NOW) goes through ``self.root`` — the chain head — so
    every layer above the shipping decision still sees it.
    """

    name = "link"

    def __init__(self):
        self.s: "RecordingSession" = None
        self.inner: Optional["LinkLayer"] = None
        self.root: Optional["LinkLayer"] = None
        self.acct = collections.Counter()

    def bind(self, session: "RecordingSession") -> None:
        self.s = session

    # -- the wire protocol surface a pass may intercept --
    def op(self, kind: str, site: str, payload=None, cdep: bool = False):
        return self.inner.op(kind, site, payload, cdep)

    def commit_now(self):
        """Ship the queued batch (how is a pass decision: blocking vs
        speculative-async)."""
        return self.inner.commit_now()

    def barrier(self):
        """Externalization point: drain the queue and validate anything
        outstanding.  Flows inward; each layer drains its own state after
        its inner layers."""
        return self.inner.barrier()

    def sync_state(self, state):
        """Post-job memory sync of the device's GPU state mirror."""
        return self.inner.sync_state(state)

    # -- accounting helpers --
    @property
    def tracer(self):
        return self.s.tracer

    def _span(self):
        return self.s.netem.checkpoint() if self.s.netem else None

    def _absorb(self, mark) -> None:
        if mark is None:
            return
        d = self.s.netem.delta(mark)
        self.acct["time_s"] += d["time_s"]
        self.acct["blocking_rts"] += d["round_trips"]
        self.acct["async_rts"] += d["async_trips"]
        self.acct["bytes"] += d["bytes_sent"] + d["bytes_received"]


class WireLink(LinkLayer):
    """Innermost base transport: the NAIVE protocol.  Every register
    access is its own blocking round trip, polling loops spin over the
    link (``POLL_TRIPS`` round trips each), and each job sync ships the
    full memory image."""

    name = "wire"

    def op(self, kind, site, payload=None, cdep=False):
        q = self.s.q
        mark = self._span()
        if kind == "write":
            q.write(site, payload)
            self.root.commit_now()
            self._absorb(mark)
            return None
        if kind == "poll":
            sym = None
            with traced(self.tracer, "wire.poll_spin", "record",
                        site=site, trips=POLL_TRIPS):
                for _ in range(POLL_TRIPS):   # unoffloaded: spin over RTTs
                    sym = q.read(site)
                    self.root.commit_now()
            self._absorb(mark)
            return sym
        sym = q.read(site)
        self.root.commit_now()
        self._absorb(mark)
        return sym

    def commit_now(self):
        self.s.q.commit()

    def barrier(self):
        if self.s.q.queue:
            self.root.commit_now()

    def sync_state(self, state):
        mark = self._span()
        wire = full_pack(state)               # naive MemSync: everything
        self.acct["sync_bytes"] += len(wire)
        with traced(self.tracer, "wire.sync", "record", bytes=len(wire)):
            self.s.ship_sync(len(wire))
            self.s.device.apply_full_sync(state)
        self._absorb(mark)


class DeferralPass(LinkLayer):
    """Register-access deferral (§4.1) + poll offloading (§4.3): ops queue
    in program order on the session's ``CommitQueue`` and ship as ONE
    round trip at control dependencies and barriers."""

    name = "deferral"

    def op(self, kind, site, payload=None, cdep=False):
        q = self.s.q
        self.acct["ops_deferred"] += 1
        if kind == "write":
            sym = None
            q.write(site, payload)
        elif kind == "poll":
            sym = q.poll(site)                # offloaded device-side loop
        else:
            sym = q.read(site)
        if cdep:                              # driver branches on this read
            self.acct["cdep_commits"] += 1
            mark = self._span()
            with traced(self.tracer, "deferral.cdep_commit", "record",
                        site=site, batch=len(q.queue)):
                self.root.commit_now()
            self._absorb(mark)
        return sym

    def barrier(self):
        if self.s.q.queue:
            self.acct["barrier_commits"] += 1
            with traced(self.tracer, "deferral.barrier_commit", "record",
                        batch=len(self.s.q.queue)):
                self.root.commit_now()
        self.inner.barrier()


class SpeculationPass(LinkLayer):
    """History-k commit speculation (§4.2): predictable commits ship
    asynchronously (wire cost, no stall) and validate at the frontier /
    at barriers; mispredicts roll the device back to the metastate
    snapshot and bill the paper's local replay recovery (§7.3)."""

    name = "speculation"
    FRONTIER = 8          # outstanding speculative commits before validate
    ROLLBACK_BASE_S = 0.5     # local log replay, no network (§7.3)
    ROLLBACK_PER_OP_S = 2.0 / 8000

    def __init__(self, k: int = 3,
                 speculator: Optional[HistorySpeculator] = None):
        super().__init__()
        self.k = k
        self.speculator = speculator
        self.runner: Optional[SpeculativeRunner] = None
        self._validated_log_len = 0

    def bind(self, session):
        super().bind(session)
        # a checkpoint is the device metastate snapshot + the log position
        # it was taken at: rollback restores the snapshot, then REPLAYS the
        # log suffix so no executed write is lost (§7.3 replay recovery).
        # An injected speculator lets a campaign share prediction history
        # across sessions of one hardware class (devices warm each other).
        spec = self.speculator if self.speculator is not None \
            else HistorySpeculator(k=self.k)
        self.runner = SpeculativeRunner(
            session.q, spec,
            lambda: (session.device.snapshot(), len(session.q.log)),
            self._rollback)

    def _rollback(self, snap, log):
        dev_snap, log_len = snap
        self.s.device.restore(dev_snap)
        # fast-forward locally: re-execute every op committed since the
        # snapshot (symbols keep their first — actual — resolutions; the
        # device is deterministic from the restored state, so it converges
        # to the exact state of a mispredict-free run).  No network.
        for op in log[log_len:]:
            self.s.device.channel(op)
        self.acct["ops_replayed"] += len(log) - log_len

    def commit_now(self):
        mark = self._span()
        went_async = self.runner.commit_speculative()
        self.acct["spec_commits" if went_async else "sync_commits"] += 1
        self._absorb(mark)
        if self.tracer:
            self.tracer.instant("spec.ship", "record",
                                mode="async" if went_async else "sync")
        if len(self.runner.outstanding) >= self.FRONTIER:
            self._validate()

    def barrier(self):
        self.inner.barrier()                  # drain queue first
        self._validate()                      # then settle speculation

    def _validate(self):
        with traced(self.tracer, "spec.validate", "record",
                    outstanding=len(self.runner.outstanding)):
            try:
                self.runner.sync()
            except MispredictError:
                # rollback-via-replay: both sides restart from the last
                # validated snapshot and fast-forward the log locally — no
                # network traffic, but real recovery time scaling with the
                # REPLAY DISTANCE (ops since the last validation), not the
                # whole session log (§7.3)
                self.acct["mispredicts"] += 1
                if self.s.netem is not None:
                    replay_ops = len(self.s.q.log) - self._validated_log_len
                    penalty = self.ROLLBACK_BASE_S + \
                        self.ROLLBACK_PER_OP_S * replay_ops
                    self.acct["rollback_s"] += penalty
                    with traced(self.tracer, "spec.rollback", "record",
                                replay_ops=replay_ops,
                                penalty_s=round(penalty, 6)):
                        self.s.netem.virtual_time_s += penalty
        self._validated_log_len = len(self.s.q.log)


class MetasyncPass(LinkLayer):
    """Metastate-only synchronization (§5): job syncs ship only the
    changed small/integer-ish descriptor leaves, delta-compressed —
    program data never crosses the link."""

    name = "metasync"

    def __init__(self):
        super().__init__()
        self.ds = DeltaSync()

    def sync_state(self, state):
        mark = self._span()
        meta, _data = split(state)
        wire = self.ds.pack(meta)
        self.acct["sync_bytes"] += len(wire)
        self.acct["leaves_skipped"] = self.ds.stats["leaves_skipped"]
        with traced(self.tracer, "metasync.sync", "record", bytes=len(wire)):
            self.s.ship_sync(len(wire))
            self.s.device.apply_meta_sync(wire)
        self._absorb(mark)


class RecordingSession:
    """One two-party record: DeviceProxy (hardware) + CloudDryrun
    (software) over a ``NetworkEmulator``, with a composable pass stack.

    ``netem=None`` is the co-located in-process degenerate: the protocol
    still runs (op logs, symbols, state mirrors), nothing is billed, and
    the manifest's session counters are zero — the LOCAL record.
    """

    def __init__(self, device: Optional[DeviceProxy] = None,
                 cloud: Optional[CloudDryrun] = None,
                 netem: Optional[NetworkEmulator] = None,
                 passes: Union[str, Sequence[str], None] = "all",
                 tracer=NULL,
                 speculator: Optional[HistorySpeculator] = None):
        self.device = device if device is not None else DeviceProxy()
        self.cloud = cloud if cloud is not None else CloudDryrun()
        self.netem = netem
        self.tracer = tracer if tracer is not None else NULL
        self.pass_names = resolve_passes(passes)
        self.q = CommitQueue(self.device.channel, netem=self.netem,
                             name="record-session")
        # canonical composition, outer -> inner, base transport last
        self.layers = [MetasyncPass()] if "metasync" in self.pass_names \
            else []
        if "deferral" in self.pass_names:
            self.layers.append(DeferralPass())
        if "speculation" in self.pass_names:
            self.layers.append(SpeculationPass(speculator=speculator))
        self.layers.append(WireLink())
        for outer, inner in zip(self.layers, self.layers[1:]):
            outer.inner = inner
        for layer in self.layers:
            layer.root = self.layers[0]
            layer.bind(self)
        self.root = self.layers[0]
        self._totals = self._zero_totals()
        self.jobs = 0
        self._first_use_site: Optional[str] = None

    # ------------------------------------------------------- constructors --
    @classmethod
    def local(cls, **kw) -> "RecordingSession":
        """In-process degenerate session (all passes, nothing billed)."""
        return cls(netem=None, **kw)

    @classmethod
    def for_profile(cls, profile: NetProfile,
                    passes: Union[str, Sequence[str], None] = "all",
                    **kw) -> "RecordingSession":
        return cls(netem=NetworkEmulator(profile), passes=passes, **kw)

    # ------------------------------------------------------------- record --
    def record(self, name: str, fn, args_abstract, **kw) -> Recording:
        """The full two-party record: cloud dryrun (lower/compile/
        serialize), then the distributed register-access protocol over the
        link, then manifest annotation.  The artifact bytes are exactly
        what ``compile_artifact`` built — the session adds cost truth,
        never payload changes."""
        rec = self.cloud.dryrun(name, fn, args_abstract, **kw)
        return self.finalize(rec)

    def finalize(self, rec: Recording) -> Recording:
        """Exercise the session protocol over an already-compiled artifact
        and annotate it — ``record()`` minus the compile.  Lets callers
        amortize ONE dryrun across a pass-stack ablation (serialized
        executables are not byte-deterministic across recompiles, so
        sharing the artifact — one session per stack — is what makes
        recordings comparable)."""
        self.exercise(rec)
        self._annotate(rec)
        return rec

    def exercise(self, rec: Recording) -> None:
        """Play the artifact's interaction plan through the pass stack.

        Single-use: device state, speculation history, delta-sync bases
        and per-pass accounting all belong to ONE recording — reuse would
        make the manifest's totals and counters disagree.  Build a fresh
        session per recording."""
        if self._first_use_site is not None:
            raise SessionReusedError(self._first_use_site)
        self._first_use_site = _caller_site()
        mark = self.netem.checkpoint() if self.netem else None
        root = self.root
        tr = self.tracer
        with tr.clock_scope(self.netem):
            for seg, ops in self.cloud.interaction_plan(rec):
                with traced(tr, f"record.{seg}", "record",
                            ops=len(ops), passes=",".join(self.pass_names)):
                    for kind, site, payload, cdep in ops:
                        root.op(kind, site, payload, cdep)
                    if seg.startswith("job"):
                        root.barrier()        # job end = externalization
                        root.sync_state(
                            self.cloud.job_state(rec, int(seg[3:])))
                        self.jobs += 1
            with traced(tr, "record.final_barrier", "record"):
                root.barrier()
        if mark is not None:
            self._totals = self.netem.delta(mark)

    # ------------------------------------------------------------ billing --
    def ship_sync(self, nbytes: int) -> None:
        """Cloud -> device state sync transfer (device is the client)."""
        if self.netem is not None:
            self.netem.one_way(nbytes, direction="recv")

    # ---------------------------------------------------------- reporting --
    @staticmethod
    def _zero_totals() -> dict:
        return {"time_s": 0.0, "round_trips": 0, "async_trips": 0,
                "bytes_sent": 0, "bytes_received": 0}

    def report(self) -> dict:
        """Session accounting for the last ``exercise``: link totals plus
        per-pass spans — the rows of the paper's record-time ablation."""
        t = self._totals
        return {
            "net": self.netem.profile.name if self.netem else "in-process",
            "passes": list(self.pass_names),
            "virtual_time_s": round(float(t["time_s"]), 6),
            "blocking_round_trips": int(t["round_trips"]),
            "async_round_trips": int(t["async_trips"]),
            "bytes_sent": int(t["bytes_sent"]),
            "bytes_received": int(t["bytes_received"]),
            "jobs": self.jobs,
            "ops_executed": len(self.device.exec_log),
            "per_pass": {layer.name: {k: round(float(v), 6)
                                      for k, v in layer.acct.items()}
                         for layer in self.layers},
        }

    def _annotate(self, rec: Recording) -> None:
        rep = self.report()
        rec.manifest["record_virtual_s"] = rep["virtual_time_s"]
        rec.manifest["record_session"] = rep


__all__ = ["RecordingSession", "SessionReusedError", "LinkLayer", "WireLink",
           "DeferralPass", "SpeculationPass", "MetasyncPass", "PASS_NAMES",
           "resolve_passes"]
