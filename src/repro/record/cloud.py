"""CloudDryrun — the "software" half of the CODY recording session.

The cloud owns the GPU software stack: it dry-runs the workload through
the JAX lower/compile path (``repro.core.recorder.compile_artifact`` — no
real data, abstract avals only) and, from the compiled artifact, derives
the *interaction plan*: the program-ordered stream of register accesses
the distributed driver must execute on the device's hardware, structured
into the driver-routine segments of the paper's Fig. 8 (init probes,
per-job power/config/doorbell/IRQ handling, offloadable polling loops)
plus a per-job memory sync.

The plan is deterministic in the artifact (job count derives from the
serialized executable size), so two sessions over the same dryrun replay
identical op logs — the invariant the record-time ablation measures
against.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.recorder import compile_artifact
from repro.core.recording import Recording

# An op is (kind, site, payload, cdep): cdep marks a control dependency —
# the real driver branches on this read, so deferral must commit here
# (§4.1); without deferral every op is its own blocking round trip.
PlanOp = Tuple[str, str, Optional[int], bool]

INIT_PROBES = 64          # boot-time register probing (paper fig. 8 "init")
PROBE_CDEP_EVERY = 16
IRQ_FILL = 8              # per-job auxiliary IRQ-handler reads
CDEP_EVERY = 5            # paper: deferral encloses ~3.8-5 accesses/commit
JOB_MIN, JOB_MAX = 12, 48
DATA_FLOOR_BYTES = 256 << 10    # modeled GPU memory image floor per job
DATA_CAP_BYTES = 1 << 20

# Readbacks the downstream plan CONSUMES at replay time: the job completion
# chain — the offloaded flush poll, the flush id it resolves to (job
# chaining orders on it: ``job_state['job']['chain_prev_id']``), and the
# final job status the replayer checks before retiring the job.  Every
# other read (init probes, pwr/cfg status, irq fills) only steered the
# live driver's record-time control flow; the recording has those branch
# outcomes baked in, so their readbacks are dead weight during replay —
# the liveness set the replay-side dead-access-elimination pass prunes to.
REPLAY_CONSUMED_SITES = frozenset(
    {"flush_poll", "latest_flush_id", "job_status"})


class CloudDryrun:
    """Drives the compile stack and emits the register-access plan.

    ``jobs`` pins the GPU job count (benchmarks use this so the ablation
    is invariant to executable size); default derives it from the
    artifact.
    """

    def __init__(self, jobs: Optional[int] = None):
        self._jobs_override = jobs
        self._heap_base: Dict[int, np.ndarray] = {}

    # ------------------------------------------------------------ dryrun --
    def dryrun(self, name: str, fn, args_abstract, **kw) -> Recording:
        """Lower + compile + serialize — the software half of the record."""
        return compile_artifact(name, fn, args_abstract, **kw)

    # -------------------------------------------------------------- plan --
    def plan_jobs(self, rec: Recording) -> int:
        if self._jobs_override is not None:
            return self._jobs_override
        return max(JOB_MIN, min(JOB_MAX, len(rec.payload) // 8192))

    def interaction_plan(self, rec: Recording) \
            -> Iterator[Tuple[str, List[PlanOp]]]:
        """Segments of ``(name, ops)``: one init segment, then one per GPU
        job.  Session plays these through the pass stack in order."""
        yield "init", [("read", f"probe_{i:03d}", None,
                        (i % PROBE_CDEP_EVERY) == PROBE_CDEP_EVERY - 1)
                       for i in range(INIT_PROBES)]
        for j in range(self.plan_jobs(rec)):
            ops: List[PlanOp] = [
                ("write", "pwr_on", 1, False),
                ("read", "pwr_status", None, True),
            ]
            ops += [("write", f"job_cfg{i}", j, False) for i in range(4)]
            ops += [("write", "job_doorbell", j, False),
                    ("poll", "flush_poll", None, True),
                    ("read", "latest_flush_id", None, True)]
            ops += [("read", f"irq_aux{i}", None,
                     (i % CDEP_EVERY) == CDEP_EVERY - 1)
                    for i in range(IRQ_FILL)]
            ops += [("read", "job_irq_status", None, True),
                    ("write", "job_irq_clear", 1, False),
                    ("read", "job_status", None, True)]
            yield f"job{j}", ops

    def consumed_readbacks(self) -> frozenset:
        """Sites whose readback the plan consumes downstream at REPLAY
        time (see ``REPLAY_CONSUMED_SITES``) — the liveness contract the
        replay-side dead-register-access-elimination pass prunes against.
        Every site in this set appears in the per-job segments of
        ``interaction_plan``; dropping any of them would change the
        consumed-readback log the compaction invariant pins."""
        return REPLAY_CONSUMED_SITES

    # --------------------------------------------------------- job state --
    def data_bytes(self, rec: Recording) -> int:
        """Per-job GPU memory image size, from the artifact's memory
        analysis (floored/capped: smoke compiles are tiny, real GPU images
        are not)."""
        mem = rec.manifest.get("memory", {})
        total = sum(int(mem.get(k, 0) or 0)
                    for k in ("arg_bytes", "temp_bytes", "out_bytes"))
        return max(DATA_FLOOR_BYTES, min(DATA_CAP_BYTES, total))

    def job_state(self, rec: Recording, j: int) -> dict:
        """GPU state after job ``j``: small integer job/ring descriptors
        (metastate — ``metasync.split`` classifies them by hint tokens and
        size) plus the big float memory image (program data).  The naive
        sync ships all of it; the metasync pass ships only the changed
        descriptor leaves."""
        elems = self.data_bytes(rec) // 4
        base = self._heap_base.get(elems)
        if base is None:
            # incompressible content — zlib must not deflate the naive
            # sync cost away; generated once, stamped per job
            base = np.random.default_rng(0).standard_normal(elems) \
                .astype(np.float32)
            self._heap_base[elems] = base
        heap = base.copy()
        heap[: min(64, elems)] = np.float32(j)
        return {
            "job": {"job_id": np.int32(j),
                    "chain_prev_id": np.int32(j - 1),
                    "slot_mask": np.full(8, j % 2, np.int32),
                    "irq_mask": np.int32(0x7)},
            "ring": {"doorbell_pos": np.int32(j % 16),
                     "submit_count": np.int32(j + 1)},
            "heap": heap,
        }


__all__ = ["CloudDryrun", "PlanOp", "INIT_PROBES", "IRQ_FILL", "CDEP_EVERY",
           "JOB_MIN", "JOB_MAX", "REPLAY_CONSUMED_SITES"]
