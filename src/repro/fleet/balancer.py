"""LoadBalancer — front-end placement + admission control for a replica
fleet.

The balancer owns the front-end queue: every arrival is ``offer()``-ed,
admission control rejects on queue pressure (an open-loop generator does
not stop arriving because the fleet is full — shedding load is the only
way to protect the tail of admitted requests), and ``dispatch()`` places
queued arrivals onto replicas that can accept them.

Placement policies (``POLICIES``):
  * ``round_robin``   — rotate a cursor over ready replicas; the baseline.
  * ``least_loaded``  — place on the replica with the fewest outstanding
    requests (ties broken by name for determinism).
  * ``cache_affinity``— pin each tenant (= recording key) to one replica
    so its executable/weights/KV working set stays hot; first placement
    is least-loaded, after that sticky.  An arrival whose pinned replica
    is full WAITS rather than spilling — that queueing-vs-locality trade
    is exactly what the policy comparison in ``BENCH_fleet.json`` shows.

Everything is deterministic: FIFO-with-skip scan order, name-tiebroken
argmins, no randomness.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence

from repro.fleet.traffic import Arrival

POLICIES = ("round_robin", "least_loaded", "cache_affinity")


class LoadBalancer:
    def __init__(self, policy: str = "round_robin", *,
                 queue_limit: Optional[int] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy '{policy}', "
                             f"expected one of {POLICIES}")
        self.policy = policy
        self.queue_limit = queue_limit
        self.queue: collections.deque = collections.deque()
        self.stats = collections.Counter()
        self._rr_cursor = 0
        self._affinity: Dict[str, str] = {}   # tenant -> replica name

    # ---------------------------------------------------------- admission --
    def offer(self, arrival: Arrival) -> bool:
        """Admission control at the front door: reject when the front-end
        queue is at its limit (load shedding), else enqueue."""
        self.stats["offered"] += 1
        if self.queue_limit is not None and \
                len(self.queue) >= self.queue_limit:
            self.stats["rejected"] += 1
            return False
        self.queue.append(arrival)
        if len(self.queue) > self.stats["queue_hwm"]:
            self.stats["queue_hwm"] = len(self.queue)
        return True

    def queue_depth(self) -> int:
        return len(self.queue)

    # ---------------------------------------------------------- placement --
    def _pick(self, arrival: Arrival, candidates: List) -> Optional[object]:
        """Choose a replica among those that can accept this arrival."""
        if not candidates:
            return None
        if self.policy == "round_robin":
            pick = candidates[self._rr_cursor % len(candidates)]
            self._rr_cursor += 1
            return pick
        if self.policy == "least_loaded":
            return min(candidates, key=lambda r: (r.load(), r.name))
        # cache_affinity: sticky tenant -> replica pin
        pinned = self._affinity.get(arrival.tenant)
        if pinned is not None:
            for r in candidates:
                if r.name == pinned:
                    return r
            return None   # pinned replica exists but is full/absent: wait
        pick = min(candidates, key=lambda r: (r.load(), r.name))
        self._affinity[arrival.tenant] = pick.name
        return pick

    def forget(self, replica_name: str):
        """Drop affinity pins to a retired replica so its tenants re-pin."""
        for tenant in [t for t, n in self._affinity.items()
                       if n == replica_name]:
            del self._affinity[tenant]

    def dispatch(self, replicas: Sequence) -> List[tuple]:
        """Place queued arrivals onto replicas: FIFO with skip — an
        arrival that no replica can accept right now stays queued (head-of-
        line arrivals for a full tenant must not block other tenants).
        Returns the ``(arrival, replica)`` placements made this call."""
        placements = []
        still: collections.deque = collections.deque()
        while self.queue:
            arrival = self.queue.popleft()
            live = [r for r in replicas if r.can_accept(arrival.tenant)]
            # pinned-policy arrivals only consider their pin (handled in
            # _pick); others take any accepting replica
            pick = self._pick(arrival, live)
            if pick is None:
                still.append(arrival)
                continue
            pick.submit(arrival)
            placements.append((arrival, pick))
            self.stats["placed"] += 1
        self.queue = still
        return placements

    # ---------------------------------------------------------- reporting --
    def snapshot(self) -> dict:
        return {
            "policy": self.policy,
            "queue_limit": self.queue_limit,
            "queue_depth": len(self.queue),
            "offered": int(self.stats["offered"]),
            "placed": int(self.stats["placed"]),
            "rejected": int(self.stats["rejected"]),
            "queue_hwm": int(self.stats["queue_hwm"]),
        }


__all__ = ["LoadBalancer", "POLICIES"]
