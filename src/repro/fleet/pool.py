"""ReplicaPool — N replay-serving replicas behind one LoadBalancer on a
deterministic tick clock.

A ``Replica`` wraps one ``Scheduler`` (its own channels, params, caches,
netem billing span — nothing shared with its siblings except the
registry it booted from).  The pool advances a virtual tick clock: each
tick injects due arrivals into the balancer, dispatches placements, lets
every ready replica with work step one scheduler round, then collects
completions — a finished request's latency is (collect clock − arrival
time), observed into ``repro.obs.metrics`` per tenant.  Because both the
traffic and the tick loop are deterministic, the whole fleet run is
replayable byte-for-byte.

Elasticity:
  * scale-up — front-end queue depth at/above ``queue_high`` for
    ``sustain_ticks`` consecutive ticks boots a new replica via the
    factory; it becomes ready ``boot_ticks`` later (a FIXED policy knob,
    not the measured boot time, so the serving timeline never depends on
    nondeterministic executable payload sizes).
  * drain-then-retire — a replica idle for ``idle_ticks`` stops
    accepting (drains), finishes what it holds, then retires; the
    balancer drops its affinity pins so tenants re-pin.
  * migration — ``migrate(tenant, src, dst)`` preempts the tenant's
    active requests on ``src`` (committed tails survive), releases its
    queue, and ``adopt()``s everything on ``dst``; deterministic decode
    resumes each stream bit-exactly (the preempt/resume invariant the
    serving tests already pin, now across replicas).
"""
from __future__ import annotations

import collections
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.fleet.balancer import LoadBalancer
from repro.fleet.traffic import Arrival


class Replica:
    """One serving replica: a Scheduler plus fleet-side bookkeeping.

    ``boot_virtual_s`` is the netem-billed virtual time its boot cost
    (registry fetch + warm-up on its OWN emulator span) — reported, never
    fed back into the tick clock.  ``pending_limit`` bounds outstanding
    requests (slot pressure admission: the balancer's ``can_accept``)."""

    def __init__(self, name: str, scheduler, *, netem=None,
                 boot_virtual_s: float = 0.0, region: int = 0,
                 pending_limit: int = 8, validate_every: int = 1):
        self.name = name
        self.scheduler = scheduler
        self.netem = netem
        self.boot_virtual_s = boot_virtual_s
        self.region = region
        self.pending_limit = pending_limit
        self.validate_every = validate_every
        self.ready_at = 0.0
        self.draining = False
        self.retired = False
        self.served = 0
        self.stats = collections.Counter()
        self._open: Dict[Tuple[str, int], int] = {}  # (tenant, rid) -> gid
        self._outstanding = 0

    # ------------------------------------------------------------- states --
    def ready(self, clock: float) -> bool:
        return not self.retired and self.ready_at <= clock

    def tenants(self) -> Tuple[str, ...]:
        return tuple(self.scheduler.streams)

    def can_accept(self, tenant: str) -> bool:
        return (not self.draining and not self.retired
                and tenant in self.scheduler.streams
                and self._outstanding < self.pending_limit)

    def load(self) -> int:
        return self._outstanding

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    # -------------------------------------------------------------- serve --
    def submit(self, arrival: Arrival) -> int:
        rid = self.scheduler.submit(arrival.tenant, list(arrival.prompt),
                                    arrival.max_new)
        self._open[(arrival.tenant, rid)] = arrival.gid
        self._outstanding += 1
        self.stats["submitted"] += 1
        return rid

    def step(self) -> int:
        self.stats["ticks_stepped"] += 1
        return self.scheduler.step(validate_every=self.validate_every)

    def collect_done(self) -> List[Tuple[int, str, List[int], bool]]:
        """Newly finished requests as (gid, tenant, tokens, failed)."""
        done = []
        for (tenant, rid), gid in list(self._open.items()):
            req = self.scheduler.streams[tenant].requests.get(rid)
            if req is not None and req.done:
                done.append((gid, tenant, list(req.generated), req.failed))
                del self._open[(tenant, rid)]
                self._outstanding -= 1
                self.served += 1
        return done

    def finish(self):
        """Final frontier drains so every in-flight tail commits."""
        for ex in self.scheduler.streams.values():
            self.scheduler.frontier.drain(ex)

    # ---------------------------------------------------------- migration --
    def release(self, tenant: str) -> List[Tuple[int, object]]:
        """Preempt + hand over every open request of ``tenant`` as
        (gid, Request) pairs — committed tails included — for another
        replica to ``adopt()``."""
        ex = self.scheduler.streams[tenant]
        if ex.slots.active_mask().any():
            self.scheduler.preempt(tenant)
        released = []
        for req in ex.release_pending():
            gid = self._open.pop((tenant, req.rid))
            self._outstanding -= 1
            released.append((gid, req))
        self.stats["released"] += len(released)
        return released

    def adopt(self, tenant: str, gid: int, req) -> int:
        rid = self.scheduler.streams[tenant].adopt(req)
        self._open[(tenant, rid)] = gid
        self._outstanding += 1
        self.stats["adopted"] += 1
        return rid

    # ---------------------------------------------------------- reporting --
    def snapshot(self) -> dict:
        return {
            "name": self.name,
            "region": self.region,
            "boot_virtual_s": round(self.boot_virtual_s, 6),
            "ready_at": round(self.ready_at, 9),
            "draining": self.draining,
            "retired": self.retired,
            "served": self.served,
            "outstanding": self._outstanding,
        }


class ReplicaPool:
    """The fleet: replicas from ``factory(idx)`` behind one balancer.

    ``factory`` builds a fully booted ``Replica`` (``Workspace.fleet``
    supplies one that boots warm from the registry on its own netem
    span).  ``run(arrivals)`` simulates open-loop serving to completion
    and returns ``{gid: tokens}``."""

    def __init__(self, factory: Callable[[int], Replica], *,
                 replicas: int = 2, policy: str = "round_robin",
                 balancer: Optional[LoadBalancer] = None,
                 name: str = "fleet", tick_s: float = 0.02,
                 queue_limit: Optional[int] = None,
                 autoscale: bool = False, queue_high: int = 8,
                 sustain_ticks: int = 5, idle_ticks: int = 50,
                 boot_ticks: int = 10, min_replicas: int = 1,
                 max_replicas: int = 8, metrics=None,
                 labels: Optional[dict] = None, max_ticks: int = 500_000):
        self.factory = factory
        self.name = name
        self.tick_s = tick_s
        self.balancer = balancer if balancer is not None else \
            LoadBalancer(policy, queue_limit=queue_limit)
        self.autoscale = autoscale
        self.queue_high = queue_high
        self.sustain_ticks = sustain_ticks
        self.idle_ticks = idle_ticks
        self.boot_ticks = boot_ticks
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.metrics = metrics
        self.labels = dict(labels or {})
        self.max_ticks = max_ticks
        self.replicas: List[Replica] = []
        self._idx = 0
        self._idle: Dict[str, int] = {}
        for _ in range(replicas):
            self._add_replica(ready_at=0.0)
        self.clock = 0.0
        self.ticks = 0
        self.outputs: Dict[int, List[int]] = {}
        self.failed: set = set()
        self.latency: Dict[int, float] = {}
        self.counters = collections.Counter()
        self._arrival_t: Dict[int, float] = {}
        self._sustain = 0

    # ----------------------------------------------------------- replicas --
    def _add_replica(self, *, ready_at: float) -> Replica:
        r = self.factory(self._idx)
        self._idx += 1
        r.ready_at = ready_at
        self.replicas.append(r)
        self._idle[r.name] = 0
        return r

    def replica(self, name: str) -> Replica:
        for r in self.replicas:
            if r.name == name:
                return r
        raise KeyError(name)

    def _alive(self) -> List[Replica]:
        return [r for r in self.replicas if not r.retired]

    # ---------------------------------------------------------- migration --
    def migrate(self, tenant: str, src_name: str, dst_name: str) -> int:
        """Move every open request of ``tenant`` from ``src`` to ``dst``
        (preempt → release → adopt); returns how many moved."""
        src, dst = self.replica(src_name), self.replica(dst_name)
        released = src.release(tenant)
        for gid, req in released:
            dst.adopt(tenant, gid, req)
        self.counters["migrations"] += 1
        self.counters["migrated_requests"] += len(released)
        return len(released)

    def drain(self, name: str):
        """Stop placing on a replica; it finishes its work then retires."""
        self.replica(name).draining = True

    # --------------------------------------------------------------- loop --
    def _inject(self, arrivals: Sequence[Arrival], i: int) -> int:
        while i < len(arrivals) and arrivals[i].t <= self.clock:
            a = arrivals[i]
            i += 1
            if self.balancer.offer(a):
                self._arrival_t[a.gid] = a.t
        return i

    def _collect(self, r: Replica):
        for gid, tenant, tokens, fail in r.collect_done():
            self.outputs[gid] = tokens
            lat = self.clock - self._arrival_t[gid]
            self.latency[gid] = lat
            if fail:
                self.failed.add(gid)
                continue
            if self.metrics is not None:
                self.metrics.histogram("fleet_request_latency_s",
                                       tenant=tenant,
                                       **self.labels).observe(lat)
                self.metrics.counter("fleet_requests_served", tenant=tenant,
                                     **self.labels).inc()

    def _can_scale_up(self) -> bool:
        return self.autoscale and len(self._alive()) < self.max_replicas

    def _autoscale_tick(self):
        if self.balancer.queue_depth() >= self.queue_high:
            self._sustain += 1
        else:
            self._sustain = 0
        if self._sustain >= self.sustain_ticks and self._can_scale_up():
            self._add_replica(
                ready_at=self.clock + self.boot_ticks * self.tick_s)
            self.counters["scale_ups"] += 1
            self._sustain = 0
        # drain-then-retire on sustained idleness
        for r in self._alive():
            if not r.ready(self.clock) or r.has_work() or \
                    self.balancer.queue_depth():
                self._idle[r.name] = 0
                continue
            self._idle[r.name] += 1
            non_draining = [x for x in self._alive() if not x.draining]
            if not r.draining and self._idle[r.name] >= self.idle_ticks \
                    and len(non_draining) > self.min_replicas:
                r.draining = True
        for r in self._alive():
            if r.draining and not r.has_work() and r.load() == 0:
                r.retired = True
                self.balancer.forget(r.name)
                self.counters["retired"] += 1

    def _fast_forward(self, arrivals: Sequence[Arrival], i: int):
        """Nothing stepped this tick: jump the clock (on the tick grid) to
        the next event instead of spinning — unless the queue is waiting
        on a sustain-triggered scale-up, which counts real ticks."""
        booting = [r.ready_at for r in self._alive()
                   if r.ready_at > self.clock]
        targets = list(booting)
        if i < len(arrivals):
            targets.append(arrivals[i].t)
        if self.balancer.queue_depth():
            if booting:
                t = min(targets)
            elif self._can_scale_up():
                return           # tick normally; sustain fires the scale-up
            else:
                stuck = sorted({a.tenant for a in self.balancer.queue})
                raise RuntimeError(
                    f"fleet '{self.name}' deadlocked: queued tenants "
                    f"{stuck} have no replica that can ever accept them")
        elif targets:
            t = min(targets)
        else:
            return
        if t > self.clock:
            n = math.ceil((t - self.clock) / self.tick_s - 1e-9)
            self.clock += n * self.tick_s
            self.counters["ticks_skipped"] += n

    def run(self, arrivals: Sequence[Arrival]) -> Dict[int, List[int]]:
        """Serve an arrival list to completion; returns {gid: tokens}
        (rejected arrivals never appear)."""
        arrivals = sorted(arrivals, key=lambda a: (a.t, a.gid))
        self.counters["arrivals"] += len(arrivals)
        i = 0
        while True:
            i = self._inject(arrivals, i)
            if i >= len(arrivals) and not self.balancer.queue_depth() and \
                    not any(r.has_work() for r in self._alive()):
                break
            ready = [r for r in self.replicas if r.ready(self.clock)]
            self.balancer.dispatch(ready)
            stepped = 0
            for r in ready:
                if r.has_work():
                    r.step()
                    stepped += 1
            self.clock += self.tick_s
            self.ticks += 1
            for r in ready:
                self._collect(r)
            if self.autoscale:
                self._autoscale_tick()
            if not stepped:
                self._fast_forward(arrivals, i)
            if self.ticks > self.max_ticks:
                raise RuntimeError(
                    f"fleet '{self.name}' exceeded max_ticks="
                    f"{self.max_ticks} (queue="
                    f"{self.balancer.queue_depth()}, served="
                    f"{len(self.outputs)})")
        for r in self._alive():
            r.finish()
            self._collect(r)
        return self.outputs

    # ---------------------------------------------------------- reporting --
    def stats(self) -> dict:
        """Pool accounting; shape pinned by
        ``repro.obs.schema.check_fleet_stats``."""
        return {
            "name": self.name,
            "policy": self.balancer.policy,
            "tick_s": self.tick_s,
            "ticks": self.ticks,
            "virtual_time_s": round(self.clock, 9),
            "arrivals": int(self.counters["arrivals"]),
            "served": len(self.outputs),
            "failed": len(self.failed),
            "migrations": int(self.counters["migrations"]),
            "balancer": self.balancer.snapshot(),
            "autoscale": {
                "enabled": self.autoscale,
                "scale_ups": int(self.counters["scale_ups"]),
                "retired": int(self.counters["retired"]),
            },
            "replicas": [r.snapshot() for r in self.replicas],
        }


__all__ = ["Replica", "ReplicaPool"]
