"""Deterministic open-loop traffic — Poisson arrivals + bursts on the
virtual clock.

The fleet bench's latency claim is only worth something under OPEN-LOOP
load: arrivals come from the outside world at their own rate, they do
not wait for the system to finish the previous request (closed-loop
generators hide queueing delay exactly when it matters).  This module
generates that arrival process deterministically:

  * NO wall clock, NO ``random`` module — every draw comes from a
    ``numpy`` generator seeded from ``(seed, tenant index)``, and every
    timestamp is a virtual-clock second.  Two generators built with the
    same mixes and seed produce byte-identical arrival lists (the
    determinism test diffs the resulting ``BENCH_fleet.json``).
  * Per-tenant Poisson processes: exponential inter-arrivals at
    ``rate_rps``, one independent substream per tenant so adding a
    tenant never perturbs another tenant's arrivals.
  * Bursts by thinning: arrivals are drawn at the burst-peak rate and
    kept with probability ``rate(t)/peak`` — an exact inhomogeneous
    Poisson process whose rate is ``burst_x`` times the base inside
    periodic burst windows (flash-crowd traffic, the p99.9 stressor).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

_IntOrRange = Union[int, Tuple[int, int]]


@dataclasses.dataclass(frozen=True)
class TenantMix:
    """One tenant's share of the open-loop mix.

    ``prompt_len`` / ``max_new`` are an exact int or an inclusive
    ``(lo, hi)`` range; replay-mode fleets pin ``prompt_len`` to the
    recorded prefill ``seq`` (a recorded executable has exactly one
    prompt shape)."""
    tenant: str
    rate_rps: float
    prompt_len: _IntOrRange = 8
    max_new: _IntOrRange = 12
    vocab: int = 256


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request: global id, virtual arrival time, tenant, payload."""
    gid: int
    t: float
    tenant: str
    prompt: Tuple[int, ...]
    max_new: int


def _draw(rng: np.random.Generator, v: _IntOrRange) -> int:
    if isinstance(v, tuple):
        lo, hi = v
        return int(rng.integers(lo, hi + 1))
    return int(v)


class OpenLoopTraffic:
    """Seeded open-loop arrival generator over a set of tenant mixes.

    ``burst_every_s``/``burst_len_s``/``burst_x`` define periodic burst
    windows (rate multiplied by ``burst_x`` while
    ``t mod burst_every_s < burst_len_s``); ``burst_x=1`` or
    ``burst_every_s=None`` is plain Poisson."""

    def __init__(self, mixes: Sequence[TenantMix], *, seed: int = 0,
                 burst_every_s: Optional[float] = None,
                 burst_len_s: float = 0.0, burst_x: float = 1.0):
        if not mixes:
            raise ValueError("OpenLoopTraffic needs at least one TenantMix")
        names = [m.tenant for m in mixes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in mix: {names}")
        if burst_x < 1.0:
            raise ValueError(f"burst_x must be >= 1, got {burst_x}")
        self.mixes = tuple(mixes)
        self.seed = seed
        self.burst_every_s = burst_every_s
        self.burst_len_s = burst_len_s
        self.burst_x = burst_x

    # ------------------------------------------------------------- rates --
    def rate_at(self, mix: TenantMix, t: float) -> float:
        """Instantaneous arrival rate for ``mix`` at virtual time ``t``."""
        if self.burst_every_s and self.burst_x > 1.0 and \
                (t % self.burst_every_s) < self.burst_len_s:
            return mix.rate_rps * self.burst_x
        return mix.rate_rps

    def in_burst(self, t: float) -> bool:
        return bool(self.burst_every_s and self.burst_x > 1.0 and
                    (t % self.burst_every_s) < self.burst_len_s)

    # ---------------------------------------------------------- generate --
    def _tenant_arrivals(self, idx: int, mix: TenantMix,
                         horizon_s: float) -> List[tuple]:
        """Thinned inhomogeneous Poisson stream for one tenant: draw at
        the peak rate, keep each point with prob rate(t)/peak."""
        rng = np.random.default_rng([self.seed, idx])
        peak = mix.rate_rps * (self.burst_x if self.burst_every_s else 1.0)
        out, t = [], 0.0
        if peak <= 0.0:
            return out
        while True:
            t += float(rng.exponential(1.0 / peak))
            # the keep/payload draws happen for every candidate point, so
            # the substream consumed per candidate is fixed and thinning
            # never shifts later draws between runs
            keep = float(rng.random()) < self.rate_at(mix, t) / peak
            prompt = tuple(int(x) for x in rng.integers(
                3, mix.vocab, _draw(rng, mix.prompt_len)))
            max_new = _draw(rng, mix.max_new)
            if t >= horizon_s:
                break
            if keep:
                out.append((t, mix.tenant, prompt, max_new))
        return out

    def generate(self, horizon_s: float) -> List[Arrival]:
        """All arrivals in ``[0, horizon_s)``, merged across tenants and
        sorted by virtual time; ``gid`` is the global arrival order."""
        rows: List[tuple] = []
        for idx, mix in enumerate(self.mixes):
            rows.extend(self._tenant_arrivals(idx, mix, horizon_s))
        rows.sort(key=lambda r: (r[0], r[1]))
        return [Arrival(gid, t, tenant, prompt, max_new)
                for gid, (t, tenant, prompt, max_new) in enumerate(rows)]


__all__ = ["TenantMix", "Arrival", "OpenLoopTraffic"]
