"""repro.fleet — fleet-scale replay serving.

A ``ReplicaPool`` of replay replicas (each booted warm from the
registry) behind an admission-controlled ``LoadBalancer``, driven by a
deterministic open-loop ``OpenLoopTraffic`` generator on a virtual tick
clock.  Built via ``Workspace.fleet(...)``; benchmarked by
``benchmarks/fleet_bench.py`` into ``BENCH_fleet.json``.
"""
from repro.fleet.balancer import POLICIES, LoadBalancer
from repro.fleet.pool import Replica, ReplicaPool
from repro.fleet.traffic import Arrival, OpenLoopTraffic, TenantMix

__all__ = ["Arrival", "LoadBalancer", "OpenLoopTraffic", "POLICIES",
           "Replica", "ReplicaPool", "TenantMix"]
