"""Roofline terms for TPU v5e from the dry-run's compiled artifact.

    T_compute    = flops_per_chip / PEAK_FLOPS
    T_memory     = hbm_bytes_per_chip / HBM_BW
    T_collective = wire_bytes_per_link / ICI_BW

flops / bytes come from repro.analysis.hlo (per-device, trip-count
corrected); MODEL_FLOPS is the analytic 6ND / 2ND budget so the
MODEL/HLO ratio exposes remat & dispatch-einsum waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


@dataclasses.dataclass
class Roofline:
    t_compute: float
    t_memory: float
    t_collective: float
    flops: float
    hbm_bytes: float
    coll_bytes: float
    model_flops_per_chip: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """How close the cell is to MXU-bound (compute roofline)."""
        t = self.step_time
        return self.t_compute / t if t else 0.0

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — 'useful' fraction of compiled compute."""
        return self.model_flops_per_chip / self.flops if self.flops else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline-predicted step time."""
        t = self.step_time
        return (self.model_flops_per_chip / PEAK_FLOPS) / t if t else 0.0

    def as_dict(self) -> Dict:
        return {
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "flops_per_chip": self.flops, "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "model_flops_per_chip": self.model_flops_per_chip,
            "model_flops_ratio": self.model_flops_ratio,
            "roofline_fraction": self.roofline_fraction, "mfu": self.mfu,
        }


def from_recording_manifest(manifest: Dict, model_flops_total: float,
                            num_chips: int = 1) -> Roofline:
    """Roofline terms from a recording's MANIFEST alone — the replay-side
    counterpart of ``from_hlo``.  A replayer never sees HLO text (only the
    serialized executable crosses the trust boundary), but the manifest
    carries XLA's own cost analysis (``cost``: 'flops', 'bytes accessed')
    captured at record time, which is enough to place the replayed
    executable on the same roofline point as its native twin: replay
    changes dispatch, not the compiled computation."""
    cost = manifest.get("cost", {}) or {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    return from_hlo({"flops": flops, "hbm_bytes": hbm, "coll_bytes": 0.0},
                    model_flops_total, num_chips)


def from_hlo(hlo_cost: Dict, model_flops_total: float, num_chips: int) -> Roofline:
    mf = model_flops_total / num_chips
    return Roofline(
        t_compute=hlo_cost["flops"] / PEAK_FLOPS,
        t_memory=hlo_cost["hbm_bytes"] / HBM_BW,
        t_collective=hlo_cost["coll_bytes"] / ICI_BW,
        flops=hlo_cost["flops"], hbm_bytes=hlo_cost["hbm_bytes"],
        coll_bytes=hlo_cost["coll_bytes"], model_flops_per_chip=mf)


def analytic_model_flops(cfg: ModelConfig, kind: str, batch: int,
                         seq: int) -> float:
    """MODEL_FLOPS: 6·N·D (train) / 2·N·D (prefill) / 2·N_active·B (decode),
    plus the attention O(S²) (train/prefill) or O(S) (decode) term."""
    n_active = cfg.param_count(active_only=True) - cfg.vocab_size * cfg.d_model \
        * (1 if cfg.tie_embeddings else 2)
    n_active += cfg.vocab_size * cfg.d_model  # lm head matmul is real compute
    hd, H = cfg.hd(), cfg.num_heads

    def attn_flops(tokens, ctx):
        if cfg.family == "ssm":
            return 0.0
        L = cfg.num_layers if cfg.family != "hybrid" \
            else cfg.num_layers // max(cfg.shared_every, 1)
        if cfg.family == "audio":
            L = cfg.num_layers  # decoder self-attn (cross handled below)
        eff_ctx = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        f = 4.0 * tokens * eff_ctx * H * hd * L
        if kind in ("train", "prefill") and not cfg.sliding_window:
            f *= 0.5  # causal
        if cfg.family == "audio":
            f += 4.0 * tokens * cfg.encdec.encoder_seq * H * hd * cfg.num_layers
        return f

    if kind == "train":
        toks = batch * seq
        return 6.0 * n_active * toks + 3.0 * attn_flops(toks, seq)
    if kind == "prefill":
        toks = batch * seq
        return 2.0 * n_active * toks + attn_flops(toks, seq)
    # decode: one token per sequence
    return 2.0 * n_active * batch + attn_flops(batch, seq)
