"""HLO-text cost analyzer with while-loop trip-count correction.

``compiled.cost_analysis()`` on this XLA counts ``while`` (lax.scan) bodies
ONCE and reports per-device values — useless for scan-over-layers models.
This analyzer parses ``compiled.as_text()`` (the post-SPMD, post-fusion,
scheduled module) and computes, per device:

  * flops       — from dot ops (2 x prod(out dims) x prod(contracting dims)),
                  counted inside fusion computations too;
  * hbm_bytes   — sum of operand+output bytes over *memory-level* ops
                  (fusion boundaries = HBM traffic; fusion internals are
                  registers/VMEM and excluded);
  * coll_bytes  — per collective type, ring-algorithm wire bytes per device:
                  AG/RS/A2A: S*(n-1)/n, AR: 2*S*(n-1)/n, CP: S.

``while`` bodies are multiplied by ``backend_config.known_trip_count`` (the
XLA annotation lax.scan loops always carry), recursively for nesting.
Cross-checked against cost_analysis() on unrolled modules in tests.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """Bytes of a shape string (handles tuples)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    attrs: str
    raw: str = ""


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    layout_bytes: float = 0.0   # entry-level param layout copies (one-time
    #                             cost in steady-state serving; reported
    #                             separately, excluded from T_memory)
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.layout_bytes += other.layout_bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + int(v * mult)

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    def as_dict(self):
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "layout_bytes": self.layout_bytes,
                "coll_bytes": self.coll_bytes, "coll": dict(self.coll),
                "coll_count": dict(self.coll_count)}


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALL_RE = re.compile(r"(?:calls|body|to_apply|condition)=%?([\w\.\-]+)")


def parse_module(text: str):
    """-> (computations: name -> [Instr], entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and "=" not in line.split("(")[0]:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, op, rest = m.groups()
        # split operands from attrs at the matching close paren
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operands_str, attrs = rest[:idx], rest[idx + 1:]
        ops = re.findall(r"%([\w\.\-]+)", operands_str)
        comps[cur].append(Instr(name, shape, op, ops, attrs, line))
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


_MEM_SKIP = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "custom-call",
             "opt-barrier"}


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_dims = _shape_dims(instr.shape)
    lhs_shape = shapes.get(instr.operands[0], "") if instr.operands else ""
    lhs_dims = _shape_dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    contract = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * contract


def _group_size(instr: Instr, num_devices: int) -> int:
    m = _GROUPS_RE.search(instr.attrs)          # [G,S]<=[N] iota form
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(instr.attrs)     # {{0,1},{2,3}} explicit form
    if m:
        return max(len(m.group(1).split(",")), 1)
    return max(num_devices, 1)


def _wire_bytes(kind: str, in_b: float, out_b: float, n: int) -> float:
    r = (n - 1) / n if n > 1 else 0.0
    if kind == "all-gather":
        return out_b * r
    if kind == "reduce-scatter":
        return in_b * r
    if kind == "all-reduce":
        return 2.0 * in_b * r
    if kind == "all-to-all":
        return max(in_b, out_b) * r
    return out_b  # collective-permute


_COND_CONST_RE = re.compile(r"constant\((\d+)\)")

# Ops that represent real HBM traffic in the fused-estimate ("spmd") mode.
# Elementwise/convert/broadcast chains are assumed fused into neighbours
# (what XLA:TPU does); reduces read their input once.
_SPMD_INOUT = {"dot", "convolution", "copy", "concatenate", "pad", "reverse",
               "sort"}
_SPMD_OUT_ONLY = {"dynamic-slice", "gather", "slice"}
_SPMD_UPDATE = {"dynamic-update-slice", "scatter"}


class Analyzer:
    def __init__(self, text: str, num_devices: int = 1, mode: str = "final"):
        """mode: 'final'  — post-fusion scheduled module (fusion boundary =
        HBM traffic; trip counts from backend_config known_trip_count);
        'spmd' — post-SPMD pre-fusion dump (dtype-true bf16; fused-estimate
        byte counting; trip counts from loop-condition constants)."""
        self.comps, self.entry = parse_module(text)
        self.num_devices = num_devices
        self.mode = mode
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def cost(self) -> Cost:
        return self.eval(self.entry, memory_level=True)

    _CHAIN_OPS = {"convert", "bitcast", "reshape", "transpose", "copy",
                  "broadcast"}

    def _source_bytes(self, name: str, imap, shapes, depth: int = 6) -> float:
        """Min bytes along the elementwise producer chain of `name` —
        approximates fused streaming reads (dequant, upcasts)."""
        best = _shape_bytes(shapes.get(name, ""))
        cur = name
        for _ in range(depth):
            it = imap.get(cur)
            if it is None:
                break
            if it.op in self._CHAIN_OPS and it.operands:
                cur = it.operands[0]
            elif it.op == "multiply" and len(it.operands) == 2:
                b0 = _shape_bytes(shapes.get(it.operands[0], ""))
                b1 = _shape_bytes(shapes.get(it.operands[1], ""))
                if min(b0, b1) * 4 <= max(b0, b1):   # scale-like factor
                    cur = it.operands[0] if b0 >= b1 else it.operands[1]
                else:
                    break
            else:
                break
            best = min(best, _shape_bytes(shapes.get(cur, "")) or best)
        return best

    def _trip_count(self, attrs: str) -> int:
        m = _TRIP_RE.search(attrs)
        if m:
            return int(m.group(1))
        mc = re.search(r"condition=%?([\w\.\-]+)", attrs)
        if mc and mc.group(1) in self.comps:
            # lax.scan conditions are `i < constant(N)` with i from 0 step 1
            consts, has_lt = [], False
            for it in self.comps[mc.group(1)]:
                consts += [int(x) for x in _COND_CONST_RE.findall(it.raw)]
                if "direction=LT" in it.raw:
                    has_lt = True
            if has_lt and consts:
                return max(consts)
        return 1

    def eval(self, comp: str, memory_level: bool) -> Cost:
        key = (comp, memory_level)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()  # guard against cycles
        total = Cost()
        instrs = self.comps.get(comp, [])
        shapes = {i.name: i.shape for i in instrs}
        for it in instrs:
            op = it.op
            out_b = _shape_bytes(it.shape)
            in_b = sum(_shape_bytes(shapes.get(o, "")) for o in it.operands)
            if op == "while":
                trip = self._trip_count(it.attrs)
                mb = re.search(r"body=%?([\w\.\-]+)", it.attrs)
                if mb:
                    total.add(self.eval(mb.group(1), memory_level), trip)
            elif op == "conditional":
                branches = re.findall(r"%([\w\.\-]+)", it.attrs)
                sub = [self.eval(b, memory_level) for b in branches
                       if b in self.comps]
                if sub:
                    best = max(sub, key=lambda c: c.flops + c.hbm_bytes)
                    total.add(best)
            elif op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", it.attrs)
                if m:
                    inner = self.eval(m.group(1), memory_level=False)
                    total.flops += inner.flops
                    for k, v in inner.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                if memory_level:
                    total.hbm_bytes += in_b + out_b
            elif op == "call":
                m = re.search(r"to_apply=%?([\w\.\-]+)", it.attrs)
                if m:
                    total.add(self.eval(m.group(1), memory_level))
            elif op == "dot":
                total.flops += _dot_flops(it, shapes)
                if memory_level:
                    if self.mode == "spmd":
                        # trace operands through elementwise chains to their
                        # HBM source (e.g. int8 dequant fused into the MXU
                        # load: count int8 bytes, not the bf16 view)
                        imap = {i.name: i for i in instrs}
                        in_tb = sum(self._source_bytes(o, imap, shapes)
                                    for o in it.operands)
                        total.hbm_bytes += in_tb + out_b
                    else:
                        total.hbm_bytes += in_b + out_b
            elif op == "convolution":
                # rough: 2 * out * (in_elems/out_spatial) — conservative
                total.flops += 2.0 * (out_b / max(_DTYPE_BYTES.get("f32"), 1)) \
                    * max(_shape_dims(shapes.get(it.operands[0], ""))[-1:] or [1])[0]
                if memory_level:
                    total.hbm_bytes += in_b + out_b
            elif any(op.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if op.startswith(c))
                if op.endswith("-done"):
                    continue
                n = _group_size(it, self.num_devices)
                wb = _wire_bytes(kind, in_b, out_b, n)
                total.coll[kind] = total.coll.get(kind, 0.0) + wb
                total.coll_count[kind] = total.coll_count.get(kind, 0) + 1
                if memory_level:
                    total.hbm_bytes += in_b + out_b
            elif op in _MEM_SKIP:
                continue
            elif self.mode == "spmd":
                if not memory_level:
                    continue
                if op in _SPMD_OUT_ONLY:
                    total.hbm_bytes += out_b
                elif op in _SPMD_UPDATE:
                    upd = _shape_bytes(shapes.get(it.operands[1], "")) \
                        if len(it.operands) > 1 else out_b
                    total.hbm_bytes += 2 * upd
                elif op == "reduce":
                    total.hbm_bytes += in_b  # one read pass; output is small
                elif op == "copy" and comp == self.entry and it.operands:
                    src = {i.name: i for i in instrs}.get(it.operands[0])
                    if src is not None and src.op == "parameter":
                        # layout normalization of an input buffer: in
                        # steady-state serving weights are pre-laid-out and
                        # carried buffers keep the loop layout (donation)
                        total.layout_bytes += in_b + out_b
                    else:
                        total.hbm_bytes += in_b + out_b
                elif op in _SPMD_INOUT:
                    total.hbm_bytes += in_b + out_b
                # elementwise / convert / broadcast: assumed fused (free)
            else:
                # memory-level elementwise / data-movement ops
                if memory_level:
                    total.hbm_bytes += in_b + out_b
        self._memo[key] = total
        return total


def analyze(text: str, num_devices: int = 1, mode: str = "final") -> Dict:
    return Analyzer(text, num_devices, mode).cost().as_dict()


def analyze_compiled(compiled, num_devices: int = 1,
                     mode: str = "final") -> Dict:
    """Analyze a live ``jax`` Compiled object (record side).  The replay
    side has no ``as_text()`` — a deserialized executable keeps only what
    the recording manifest carried — so benches comparing native vs replay
    pair this with ``roofline.from_recording_manifest`` to show both modes
    sit at the same roofline point."""
    return analyze(compiled.as_text(), num_devices, mode)


def top_collectives(text: str, num_devices: int = 1, k: int = 20):
    """Debug: largest collectives with while-trip multipliers applied."""
    an = Analyzer(text, num_devices)
    mults: Dict[str, float] = {an.entry: 1.0}
    order = [an.entry]
    while order:  # propagate multipliers through while nesting
        comp = order.pop()
        for it in an.comps.get(comp, []):
            if it.op == "while":
                m = _TRIP_RE.search(it.attrs)
                trip = int(m.group(1)) if m else 1
                mb = re.search(r"body=%?([\w\.\-]+)", it.attrs)
                if mb:
                    mults[mb.group(1)] = mults.get(comp, 1.0) * trip
                    order.append(mb.group(1))
    rows = []
    for comp, mult in mults.items():
        shapes = {i.name: i.shape for i in an.comps.get(comp, [])}
        for it in an.comps.get(comp, []):
            kind = next((c for c in COLLECTIVES if it.op.startswith(c)), None)
            if not kind or it.op.endswith("-done"):
                continue
            in_b = sum(_shape_bytes(shapes.get(o, "")) for o in it.operands)
            out_b = _shape_bytes(it.shape)
            n = _group_size(it, num_devices)
            rows.append((_wire_bytes(kind, in_b, out_b, n) * mult, kind,
                         it.shape[:60], f"x{mult:.0f}", comp[:40],
                         it.attrs[:80]))
    rows.sort(reverse=True)
    return rows[:k]
