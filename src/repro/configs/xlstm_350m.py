"""xlstm-350m [ssm] — mLSTM blocks with sLSTM at layers {3,9,15,21};
no standalone FFN (d_ff=0; blocks carry their own projections).
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304, max_seq=532480,
    attention="none", rope_theta=0.0,
    xlstm=XLSTMConfig(slstm_at=(3, 9, 15, 21), proj_factor_m=2.0,
                      proj_factor_s=1.3334, chunk=256),
)
