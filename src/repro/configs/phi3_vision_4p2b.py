"""phi-3-vision-4.2b [vlm] — phi3-mini text backbone; CLIP vision frontend
is a STUB: input_specs() provides precomputed (B, 576, d_model) patch
embeddings prepended to the text sequence.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064, max_seq=532480,
    attention="gqa", rope_theta=1e4,
    vlm=VLMConfig(num_image_tokens=576),
)
