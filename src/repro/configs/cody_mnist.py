"""Paper-faithful workload: a small MLP-mixer-style MNIST classifier, the
class of workload the paper records (MNIST inference, Table 1).  Used by the
record/replay benchmarks to reproduce Fig. 7 / Tables 1-2 quantitatively.

Modeled as a tiny dense transformer over 49 patch tokens (28x28 / 4x4),
which keeps it inside the unified stage-structured model."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="cody-mnist", family="dense",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=256, max_seq=64,
    attention="gqa", rope_theta=1e4,
)
