"""Assigned input shapes + abstract input specs for every (arch x shape) cell.

Shapes (per assignment; identical across the 10 LM-family archs):
    train_4k     seq 4,096   global_batch 256   -> lowers train_step
    prefill_32k  seq 32,768  global_batch 32    -> lowers serve prefill
    decode_32k   seq 32,768  global_batch 128   -> lowers serve decode (1 tok)
    long_500k    seq 524,288 global_batch 1     -> serve decode; sub-quadratic
                                                   archs only (see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> Optional[str]:
    """None if runnable, else a skip reason (recorded in EXPERIMENTS.md)."""
    if shape == "long_500k" and not cfg.is_subquadratic():
        return "pure full-attention arch: 500k context requires sub-quadratic attention"
    return None


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: str,
                batch_override: int = 0, seq_override: int = 0) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    cell = SHAPES[shape]
    B = batch_override or cell.batch
    S = seq_override or cell.seq
    bf = jnp.dtype(cfg.dtype)

    if cell.kind in ("train", "prefill"):
        batch = {"tokens": _tok(B, S), "labels": _tok(B, S)}
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encdec.encoder_seq, cfg.d_model), bf)
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vlm.num_image_tokens, cfg.d_model), bf)
        if cell.kind == "prefill":
            batch.pop("labels")
        return batch

    # decode: one new token against a cache of length S
    enc_S = cfg.encdec.encoder_seq if cfg.family == "audio" else 0
    caches = jax.eval_shape(
        lambda: M.init_cache(cfg, B, S, enc_S=enc_S))
    return {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        "caches": caches,
    }


def smoke_shrink(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, 2), d_model=64,
        num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 2) or 2,
        head_dim=16, d_ff=128, vocab_size=256, max_seq=256,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2), expert_d_ff=64)
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=8, head_dim=8, chunk=16)
    if cfg.xlstm is not None:
        small["num_layers"] = 6
        small["xlstm"] = dataclasses.replace(cfg.xlstm, slstm_at=(3,), chunk=16)
    if cfg.mla is not None:
        small["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16)
        small["num_layers"] = 3
    if cfg.encdec is not None:
        small["encdec"] = dataclasses.replace(
            cfg.encdec, num_encoder_layers=2, encoder_seq=24)
    if cfg.vlm is not None:
        small["vlm"] = dataclasses.replace(cfg.vlm, num_image_tokens=8)
    if cfg.family == "hybrid":
        small["num_layers"] = 4
        small["shared_every"] = 2
    if cfg.dense_first_layer_d_ff:
        small["dense_first_layer_d_ff"] = 128
    small["name"] = cfg.name + "-smoke"
    small.update(over)
    return dataclasses.replace(cfg, **small)
