"""deepseek-v2-lite-16b [moe] — MLA (kv_lora 512), 64 routed top-6 +
2 shared experts, dense layer 0. [arXiv:2405.04434; hf]"""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400, max_seq=163840,
    attention="mla", rope_theta=1e4,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                  expert_d_ff=1408, capacity_factor=1.25, group_size=256),
    dense_first_layer_d_ff=10944,
)
