"""starcoder2-7b [dense] — GQA, RoPE, sliding-window 4096, bias.
[arXiv:2402.19173; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size=49152, max_seq=532480,
    attention="gqa", rope_theta=1e5, qkv_bias=True, mlp_bias=True,
    sliding_window=4096,
)
