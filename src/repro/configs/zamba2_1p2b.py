"""zamba2-1.2b [hybrid] — Mamba2 backbone (ssm_state 64) + shared
attention+MLP block applied every 6 SSM layers (weights shared across
applications, zamba-style). [arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=36, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, max_seq=532480,
    attention="gqa", rope_theta=1e4,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256),
    shared_every=6,
)
