"""command-r-35b [dense] — GQA, parallel attn+FFN block, no bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000, max_seq=532480,
    attention="gqa", rope_theta=8e6, qkv_bias=False,
    parallel_block=True, logit_scale=0.0625, norm="layernorm",
)
