"""qwen2.5-3b [dense] — GQA, QKV bias, tied embeddings.
[hf:Qwen/Qwen2.5-3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2, head_dim=128,
    d_ff=11008, vocab_size=151936, max_seq=532480,
    attention="gqa", rope_theta=1e6, qkv_bias=True, tie_embeddings=True,
)
