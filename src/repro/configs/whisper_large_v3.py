"""whisper-large-v3 [audio] — encoder-decoder backbone; conv frontend is a
STUB: input_specs() provides precomputed (B, 1500, 1280) frame embeddings.
Learned positions (rope disabled), LayerNorm, GELU MLP with bias.
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig, EncDecConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866, max_seq=33792,
    attention="gqa", rope_theta=0.0, qkv_bias=True, mlp_bias=True,
    norm="layernorm", act="gelu",
    encdec=EncDecConfig(num_encoder_layers=32, encoder_seq=1500),
)
