"""mixtral-8x22b [moe] — GQA, 8 experts top-2, SWA (per assignment).
[arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768, max_seq=532480,
    attention="gqa", rope_theta=1e6, sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=16384,
                  capacity_factor=1.25, group_size=1024),
)
