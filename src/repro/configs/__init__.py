"""Architecture registry: ``get_config(name)`` / ``ARCHS``."""
from __future__ import annotations

import importlib

from repro.configs.common import (SHAPES, ShapeCell, cell_applicable,
                                  input_specs, smoke_shrink)

_MODULES = {
    "command-r-35b": "command_r_35b",
    "qwen2-72b": "qwen2_72b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen2.5-3b": "qwen2p5_3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "whisper-large-v3": "whisper_large_v3",
    "xlstm-350m": "xlstm_350m",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "zamba2-1.2b": "zamba2_1p2b",
    "cody-mnist": "cody_mnist",
}

ARCHS = tuple(k for k in _MODULES if k != "cody-mnist")


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


__all__ = ["ARCHS", "get_config", "SHAPES", "ShapeCell", "cell_applicable",
           "input_specs", "smoke_shrink"]
