"""Registry client — the device (TEE) side of the recording registry.

All traffic is billed to a ``NetworkEmulator`` so the benchmarks report
the real byte/RTT cost per profile (wifi/cellular):

  * one blocking round trip for the index/lease RPC;
  * a miss with record-on-miss blocks on the cloud's single-flight lease:
    the recorder's wall time is added to virtual time (this is the cold
    cost a warm hit avoids) and counted in ``stats['recording_round_trips']``;
  * chunk downloads go through ``NetworkEmulator.transfer`` — pipelined,
    ack-accounted, billed only for chunks the client does not already
    hold (the chunk cache is content-addressed, so after a delta
    re-publish a refetch downloads only the changed chunks).

Fetches are RESUMABLE: received chunks live in a byte-bounded LRU keyed
by content address, so an interrupted fetch retries with only the
missing remainder.

Security: the client verifies the recording HMAC (``Recording.from_bytes``
with a key — never ``allow_unsigned``) BEFORE the bytes can reach any
``pickle.loads``; the store additionally re-verifies every chunk digest
and the signed index on each read.  On top of that, every fetch demands
a transparency-log INCLUSION proof (the fetched bytes are the published
bytes, committed under a signed tree head) and a CONSISTENCY proof
against the head pinned on the previous fetch (the log only ever grew) —
``SplitViewError`` on a silent swap or forked log, still pre-unpickle.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

from repro.attest.keys import KeySchedule
from repro.attest.log import (PROOF_HASH_BYTES, leaf_data, proof_wire_bytes,
                              verify_consistency, verify_inclusion)
from repro.attest.verifier import head_signable
from repro.core.attest import FutureEpochError, SplitViewError, fingerprint
from repro.core.recording import Recording
from repro.obs.trace import NULL, traced
from repro.registry.service import RegistryService, parts_to_recording_bytes
from repro.registry.store import LRUBytes, RegistryMissError

_INDEX_RPC_SEND = 96          # key + auth token
_INDEX_RPC_RECV_BASE = 64     # entry header
_INDEX_RPC_RECV_PER_CHUNK = 48  # digest + sizes per chunk row


class FetchInterrupted(RuntimeError):
    """A chunked fetch was cut off mid-stream; already-received chunks are
    cached, so retrying the fetch resumes where it stopped."""


class RegistryClient:
    def __init__(self, service: RegistryService, netem=None, *, key: bytes,
                 cache_bytes: int = 32 << 20, tracer=None,
                 keys: Optional[KeySchedule] = None,
                 verify_proofs: bool = True):
        if not key:
            raise ValueError("RegistryClient requires the registry signing "
                             "key: fetched bytes are verified before use")
        self._svc = service
        self._net = netem
        self._key = key
        self.tracer = tracer if tracer is not None else NULL
        self.chunks = LRUBytes(cache_bytes)   # digest -> raw chunk
        self.stats = collections.Counter()
        # transparency-log verification: the client pins the last signed
        # tree head it accepted and demands (inclusion + consistency)
        # proofs on every fetch.  ``keys`` shares the Workspace's epoch
        # schedule; a bare client derives one from the signing key (same
        # derivation the service uses, so epoch 0 agrees by construction)
        self._keys = keys if keys is not None else KeySchedule(key)
        self._verify_proofs = verify_proofs
        self._sth: Optional[dict] = None      # pinned {size, root}

    # ---------------------------------------------------------- internals --
    def _bill_index_rpc(self, n_chunks: int):
        if self._net is not None:
            self._net.round_trip(
                send_bytes=_INDEX_RPC_SEND,
                recv_bytes=_INDEX_RPC_RECV_BASE +
                _INDEX_RPC_RECV_PER_CHUNK * n_chunks)

    def _missing_rows(self, entry: dict) -> List[dict]:
        """Chunk rows not in the local cache, deduplicated by digest — a
        digest repeated across index rows (e.g. identical zero pages)
        crosses the wire once."""
        seen, rows = set(), []
        for c in entry["chunks"]:
            if c["d"] not in self.chunks and c["d"] not in seen:
                seen.add(c["d"])
                rows.append(c)
        return rows

    def _download(self, chunk_rows: List[dict],
                  stat_key: str = "chunks_fetched",
                  cache: bool = True) -> Dict[str, bytes]:
        """Pull the given chunks, billing ONE pipelined transfer for their
        total compressed size.  ``cache=False`` keeps the result out of
        the LRU (refetches of evicted chunks must not thrash it) and
        returns the raw bytes instead."""
        out: Dict[str, bytes] = {}
        if not chunk_rows:
            return out
        with traced(self.tracer, "registry.download", "registry",
                    chunks=len(chunk_rows),
                    bytes=sum(c["c"] for c in chunk_rows), kind=stat_key):
            if self._net is not None:
                self._net.transfer(sum(c["c"] for c in chunk_rows),
                                   chunk_size=self._svc.chunk_size,
                                   direction="recv")
        for c in chunk_rows:
            raw = self._svc.read_chunk(c["d"])
            if cache:
                self.chunks.put(c["d"], raw)
            else:
                out[c["d"]] = raw
            self.stats[stat_key] += 1
            self.stats["chunk_bytes_fetched"] += c["c"]
        return out

    # ------------------------------------------------------------- public --
    def fetch(self, key: str,
              record_fn: Optional[Callable[[], Recording]] = None,
              interrupt_after: Optional[int] = None) -> bytes:
        """Fetch-and-verify a recording; returns the verified wire bytes.

        ``record_fn`` enables record-on-miss (single-flight on the cloud
        side).  ``interrupt_after=k`` aborts after k newly received chunks
        with ``FetchInterrupted`` — the test/demo hook for resumability.
        """
        with self.tracer.clock_scope(self._net), \
                traced(self.tracer, "registry.fetch", "registry", key=key):
            return self._fetch(key, record_fn, interrupt_after)

    def _fetch(self, key, record_fn, interrupt_after) -> bytes:
        tr = self.tracer
        if not self._svc.has(key):
            if record_fn is None:
                self._bill_index_rpc(0)
                raise RegistryMissError(key)
            if tr:
                tr.instant("registry.miss", "registry", key=key)
            # blocking record-on-miss RPC: the client stalls for the
            # cloud's record (or for another client's in-flight lease);
            # ensure() publishes without reassembling — the chunks cross
            # the wire exactly once, in the billed download below
            self._svc.ensure(key, record_fn)
            entry = self._svc.entry(key)
            self._bill_index_rpc(len(entry["chunks"]))
            self.stats["recording_round_trips"] += 1
            if self._net is not None:
                # the cold cost a warm hit avoids: the cloud's compile wall
                # time PLUS the distributed record session's virtual time
                # (the device<->cloud protocol round trips; zero when the
                # recording was made by a local in-process session)
                with traced(tr, "registry.record_on_miss", "registry",
                            key=key):
                    self._net.virtual_time_s += \
                        float(entry["meta"].get("record_wall_s", 0.0)) + \
                        float(entry["meta"].get("record_virtual_s", 0.0))
        else:
            entry = self._svc.entry(key)
            self._bill_index_rpc(len(entry["chunks"]))
            self.stats["registry_hits"] += 1
            if tr:
                tr.instant("registry.hit", "registry", key=key)

        missing = self._missing_rows(entry)
        if interrupt_after is not None and len(missing) > interrupt_after:
            self._download(missing[:interrupt_after])
            raise FetchInterrupted(
                f"fetch of '{key}' interrupted: "
                f"{interrupt_after}/{len(missing)} missing chunks received "
                f"(resume by fetching again)")
        self._download(missing)

        # chunks the LRU evicted mid-fetch (cache smaller than the
        # recording) must cross the wire AGAIN — billed, and kept out of
        # the cache to avoid thrashing it
        extra = self._download(self._missing_rows(entry),
                               stat_key="chunks_refetched", cache=False)

        parts: Dict[str, List[bytes]] = {}
        for c in entry["chunks"]:
            raw = extra.get(c["d"])
            if raw is None:
                raw = self.chunks.get(c["d"])
            if raw is None:
                # evicted between the refetch scan and here (only possible
                # with a concurrently shared cache) — still billed
                raw = self._download([c], stat_key="chunks_refetched",
                                     cache=False)[c["d"]]
            parts.setdefault(c["part"], []).append(raw)
        blob = parts_to_recording_bytes(
            {p: b"".join(pieces) for p, pieces in parts.items()})
        # HMAC verification BEFORE the blob can reach pickle.loads anywhere
        rec = Recording.from_bytes(blob, self._key)
        # ... and transparency-log verification before the bytes are
        # TRUSTED: inclusion of exactly these bytes under a signed root,
        # consistency of that root with the head pinned on the previous
        # fetch.  A silently swapped recording or a forked log raises
        # SplitViewError here — still before any unpickle.
        if self._verify_proofs and hasattr(self._svc, "proof_for"):
            self._verify_published(key, rec)
        self.stats["verified_fetches"] += 1
        if self.tracer:
            self.tracer.instant("registry.verified", "registry", key=key,
                                bytes=len(blob))
        return blob

    def _verify_published(self, key: str, rec: Recording) -> None:
        """Verify the fetched recording against the transparency log:
        signed head -> leaf == fetched bytes -> inclusion -> consistency
        with the pinned head.  Proof bytes are billed as ASYNC wire bytes
        (they piggyback on the chunk stream; no extra blocking RTT — the
        <=5% warm-fetch overhead gate depends on this)."""
        bundle = self._svc.proof_for(key)
        head, leaf = bundle["head"], bundle["leaf"]
        try:
            head_ok = self._keys.verify(head_signable(head),
                                        head["signature"])
        except FutureEpochError as e:
            raise SplitViewError(f"tree head for '{key}': {e}")
        if not head_ok:
            raise SplitViewError(
                f"signed tree head for '{key}' does not verify under the "
                "epoch key schedule")
        if (leaf["key"] != key
                or leaf["manifest_fp"] != fingerprint(rec.manifest)
                or leaf["payload_digest"] != fingerprint(rec.payload)):
            raise SplitViewError(
                f"registry served bytes for '{key}' that do not match its "
                "published log leaf: silent recording swap detected")
        data = leaf_data(leaf["key"], leaf["manifest_fp"],
                         leaf["payload_digest"], leaf["epoch"])
        if not verify_inclusion(data, bundle["index"], head["size"],
                                bundle["path"], head["root"]):
            raise SplitViewError(
                f"inclusion proof for '{key}' does not fold up to the "
                "signed root")
        cons_hashes = 0
        if self._sth is not None and self._sth["size"] > 0:
            old_size, old_root = self._sth["size"], self._sth["root"]
            if head["size"] < old_size:
                raise SplitViewError(
                    f"log shrank from {old_size} to {head['size']} "
                    "entries: append-only violation")
            cp = self._svc.consistency_between(old_size, head["size"])
            if not verify_consistency(old_size, old_root, head["size"],
                                      head["root"], cp["proof"]):
                raise SplitViewError(
                    f"consistency proof {old_size} -> {head['size']} "
                    "failed: the registry is serving a forked (split-view) "
                    "log")
            cons_hashes = len(cp["proof"])
        self._sth = {"size": head["size"], "root": head["root"]}
        pb = proof_wire_bytes(bundle["path"]) + \
            cons_hashes * PROOF_HASH_BYTES
        if self._net is not None:
            self._net.async_trip(send_bytes=0, recv_bytes=pb)
        self.stats["proof_bytes"] += pb
        self.stats["proofs_verified"] += 1
        if self.tracer:
            self.tracer.instant("registry.proof_verified", "registry",
                                key=key, log_size=head["size"],
                                proof_bytes=pb)

    def into_channel(self, replayer, prefill_item, decode_item,
                     warm: bool = True):
        """Warm handoff targeting an ``ExecutionChannel``: fetch + verify
        the prefill/decode recordings, preload them into ``replayer``, and
        return a ready ``ReplayChannel`` — the serving stack never sees the
        Replayer.  Items are ``key`` or ``(key, record_fn)`` as in
        ``into_replayer``."""
        from repro.core.channel import ReplayChannel
        pre, dec = self.into_replayer(replayer, [prefill_item, decode_item],
                                      warm=warm)
        return ReplayChannel(replayer, pre, dec)

    def into_replayer(self, replayer,
                      keys: Iterable[Union[str, Tuple[str, Optional[
                          Callable[[], Recording]]]]],
                      warm: bool = True) -> List[str]:
        """Warm handoff: fetch + verify each key, preload into a
        ``Replayer`` under the registry key as the executable-cache name,
        and (optionally) warm-execute so a replica boots from a registry
        hit without recompiling — the first real request pays neither
        compile nor cold-start cost."""
        items = []
        for it in keys:
            key, record_fn = it if isinstance(it, tuple) else (it, None)
            items.append((self.fetch(key, record_fn), key))
        names = replayer.preload(items)
        if warm:
            for name in names:
                replayer.warm(name)
        return names
