"""Registry client — the device (TEE) side of the recording registry.

All traffic is billed to a ``NetworkEmulator`` so the benchmarks report
the real byte/RTT cost per profile (wifi/cellular):

  * one blocking round trip for the index/lease RPC;
  * a miss with record-on-miss blocks on the cloud's single-flight lease:
    the recorder's wall time is added to virtual time (this is the cold
    cost a warm hit avoids) and counted in ``stats['recording_round_trips']``;
  * chunk downloads go through ``NetworkEmulator.transfer`` — pipelined,
    ack-accounted, billed only for chunks the client does not already
    hold (the chunk cache is content-addressed, so after a delta
    re-publish a refetch downloads only the changed chunks).

Fetches are RESUMABLE: received chunks live in a byte-bounded LRU keyed
by content address, so an interrupted fetch retries with only the
missing remainder.

Security: the client verifies the recording HMAC (``Recording.from_bytes``
with a key — never ``allow_unsigned``) BEFORE the bytes can reach any
``pickle.loads``; the store additionally re-verifies every chunk digest
and the signed index on each read.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

from repro.core.recording import Recording
from repro.obs.trace import NULL, traced
from repro.registry.service import RegistryService, parts_to_recording_bytes
from repro.registry.store import LRUBytes, RegistryMissError

_INDEX_RPC_SEND = 96          # key + auth token
_INDEX_RPC_RECV_BASE = 64     # entry header
_INDEX_RPC_RECV_PER_CHUNK = 48  # digest + sizes per chunk row


class FetchInterrupted(RuntimeError):
    """A chunked fetch was cut off mid-stream; already-received chunks are
    cached, so retrying the fetch resumes where it stopped."""


class RegistryClient:
    def __init__(self, service: RegistryService, netem=None, *, key: bytes,
                 cache_bytes: int = 32 << 20, tracer=None):
        if not key:
            raise ValueError("RegistryClient requires the registry signing "
                             "key: fetched bytes are verified before use")
        self._svc = service
        self._net = netem
        self._key = key
        self.tracer = tracer if tracer is not None else NULL
        self.chunks = LRUBytes(cache_bytes)   # digest -> raw chunk
        self.stats = collections.Counter()

    # ---------------------------------------------------------- internals --
    def _bill_index_rpc(self, n_chunks: int):
        if self._net is not None:
            self._net.round_trip(
                send_bytes=_INDEX_RPC_SEND,
                recv_bytes=_INDEX_RPC_RECV_BASE +
                _INDEX_RPC_RECV_PER_CHUNK * n_chunks)

    def _missing_rows(self, entry: dict) -> List[dict]:
        """Chunk rows not in the local cache, deduplicated by digest — a
        digest repeated across index rows (e.g. identical zero pages)
        crosses the wire once."""
        seen, rows = set(), []
        for c in entry["chunks"]:
            if c["d"] not in self.chunks and c["d"] not in seen:
                seen.add(c["d"])
                rows.append(c)
        return rows

    def _download(self, chunk_rows: List[dict],
                  stat_key: str = "chunks_fetched",
                  cache: bool = True) -> Dict[str, bytes]:
        """Pull the given chunks, billing ONE pipelined transfer for their
        total compressed size.  ``cache=False`` keeps the result out of
        the LRU (refetches of evicted chunks must not thrash it) and
        returns the raw bytes instead."""
        out: Dict[str, bytes] = {}
        if not chunk_rows:
            return out
        with traced(self.tracer, "registry.download", "registry",
                    chunks=len(chunk_rows),
                    bytes=sum(c["c"] for c in chunk_rows), kind=stat_key):
            if self._net is not None:
                self._net.transfer(sum(c["c"] for c in chunk_rows),
                                   chunk_size=self._svc.chunk_size,
                                   direction="recv")
        for c in chunk_rows:
            raw = self._svc.read_chunk(c["d"])
            if cache:
                self.chunks.put(c["d"], raw)
            else:
                out[c["d"]] = raw
            self.stats[stat_key] += 1
            self.stats["chunk_bytes_fetched"] += c["c"]
        return out

    # ------------------------------------------------------------- public --
    def fetch(self, key: str,
              record_fn: Optional[Callable[[], Recording]] = None,
              interrupt_after: Optional[int] = None) -> bytes:
        """Fetch-and-verify a recording; returns the verified wire bytes.

        ``record_fn`` enables record-on-miss (single-flight on the cloud
        side).  ``interrupt_after=k`` aborts after k newly received chunks
        with ``FetchInterrupted`` — the test/demo hook for resumability.
        """
        with self.tracer.clock_scope(self._net), \
                traced(self.tracer, "registry.fetch", "registry", key=key):
            return self._fetch(key, record_fn, interrupt_after)

    def _fetch(self, key, record_fn, interrupt_after) -> bytes:
        tr = self.tracer
        if not self._svc.has(key):
            if record_fn is None:
                self._bill_index_rpc(0)
                raise RegistryMissError(key)
            if tr:
                tr.instant("registry.miss", "registry", key=key)
            # blocking record-on-miss RPC: the client stalls for the
            # cloud's record (or for another client's in-flight lease);
            # ensure() publishes without reassembling — the chunks cross
            # the wire exactly once, in the billed download below
            self._svc.ensure(key, record_fn)
            entry = self._svc.entry(key)
            self._bill_index_rpc(len(entry["chunks"]))
            self.stats["recording_round_trips"] += 1
            if self._net is not None:
                # the cold cost a warm hit avoids: the cloud's compile wall
                # time PLUS the distributed record session's virtual time
                # (the device<->cloud protocol round trips; zero when the
                # recording was made by a local in-process session)
                with traced(tr, "registry.record_on_miss", "registry",
                            key=key):
                    self._net.virtual_time_s += \
                        float(entry["meta"].get("record_wall_s", 0.0)) + \
                        float(entry["meta"].get("record_virtual_s", 0.0))
        else:
            entry = self._svc.entry(key)
            self._bill_index_rpc(len(entry["chunks"]))
            self.stats["registry_hits"] += 1
            if tr:
                tr.instant("registry.hit", "registry", key=key)

        missing = self._missing_rows(entry)
        if interrupt_after is not None and len(missing) > interrupt_after:
            self._download(missing[:interrupt_after])
            raise FetchInterrupted(
                f"fetch of '{key}' interrupted: "
                f"{interrupt_after}/{len(missing)} missing chunks received "
                f"(resume by fetching again)")
        self._download(missing)

        # chunks the LRU evicted mid-fetch (cache smaller than the
        # recording) must cross the wire AGAIN — billed, and kept out of
        # the cache to avoid thrashing it
        extra = self._download(self._missing_rows(entry),
                               stat_key="chunks_refetched", cache=False)

        parts: Dict[str, List[bytes]] = {}
        for c in entry["chunks"]:
            raw = extra.get(c["d"])
            if raw is None:
                raw = self.chunks.get(c["d"])
            if raw is None:
                # evicted between the refetch scan and here (only possible
                # with a concurrently shared cache) — still billed
                raw = self._download([c], stat_key="chunks_refetched",
                                     cache=False)[c["d"]]
            parts.setdefault(c["part"], []).append(raw)
        blob = parts_to_recording_bytes(
            {p: b"".join(pieces) for p, pieces in parts.items()})
        # HMAC verification BEFORE the blob can reach pickle.loads anywhere
        Recording.from_bytes(blob, self._key)
        self.stats["verified_fetches"] += 1
        if self.tracer:
            self.tracer.instant("registry.verified", "registry", key=key,
                                bytes=len(blob))
        return blob

    def into_channel(self, replayer, prefill_item, decode_item,
                     warm: bool = True):
        """Warm handoff targeting an ``ExecutionChannel``: fetch + verify
        the prefill/decode recordings, preload them into ``replayer``, and
        return a ready ``ReplayChannel`` — the serving stack never sees the
        Replayer.  Items are ``key`` or ``(key, record_fn)`` as in
        ``into_replayer``."""
        from repro.core.channel import ReplayChannel
        pre, dec = self.into_replayer(replayer, [prefill_item, decode_item],
                                      warm=warm)
        return ReplayChannel(replayer, pre, dec)

    def into_replayer(self, replayer,
                      keys: Iterable[Union[str, Tuple[str, Optional[
                          Callable[[], Recording]]]]],
                      warm: bool = True) -> List[str]:
        """Warm handoff: fetch + verify each key, preload into a
        ``Replayer`` under the registry key as the executable-cache name,
        and (optionally) warm-execute so a replica boots from a registry
        hit without recompiling — the first real request pays neither
        compile nor cold-start cost."""
        items = []
        for it in keys:
            key, record_fn = it if isinstance(it, tuple) else (it, None)
            items.append((self.fetch(key, record_fn), key))
        names = replayer.preload(items)
        if warm:
            for name in names:
                replayer.warm(name)
        return names
