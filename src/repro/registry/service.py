"""Registry service — the CODY cloud side of the recording registry.

Responsibilities:
  * fetch-by-key: reassemble a published recording from the
    content-addressed store (integrity re-verified by the store);
  * record-on-miss with SINGLE-FLIGHT leases: N concurrent clients
    requesting the same (arch, kind, shapes, mesh) key trigger exactly one
    ``recorder.record()`` — the first requester takes the lease and
    records, the rest block on it and reuse the published result (the
    whole point of record-once/replay-everywhere: the expensive dryrun
    happens once per key, fleet-wide);
  * delta publishing: consecutive versions of a key go through one
    ``metasync.DeltaSync`` instance per key, so a re-record after a config
    tweak ships only the changed parts (typically manifest + signature —
    the payload chunks dedupe by content address in the store too).
"""
from __future__ import annotations

import collections
import inspect
import json
import threading
import time
from typing import Callable, Dict, Optional

import msgpack
import numpy as np

from repro.attest.keys import KeySchedule
from repro.attest.log import TransparencyLog, leaf_data
from repro.attest.verifier import head_signable
from repro.core.attest import (AttestationError, TamperedRecordingError,
                               fingerprint, verify)
from repro.core.metasync import DeltaSync
from repro.core.recording import Recording
from repro.obs.trace import NULL, traced
from repro.registry.store import (RecordingStore, RegistryMissError,
                                  split_chunks)


def recording_to_parts(rec: Recording, chunk_size: int) -> Dict[str, bytes]:
    """Recording -> ordered, path-keyed byte sections.  The payload is
    pre-split at chunk boundaries so a payload-local change invalidates
    only its own chunks (chunking the whole serialized blob would let a
    one-byte manifest edit shift — and re-address — every payload chunk)."""
    parts = {"manifest": msgpack.packb(rec.manifest, use_bin_type=True)}
    for i, chunk in enumerate(split_chunks(rec.payload, chunk_size)):
        parts[f"payload/{i:06d}"] = chunk
    parts["trees"] = rec.trees
    parts["signature"] = rec.signature.encode()
    return parts


def parts_to_recording_bytes(parts: Dict[str, bytes]) -> bytes:
    """Inverse of ``recording_to_parts`` — a wire-format recording blob
    (the caller still verifies its HMAC before trusting it)."""
    missing = [k for k in ("manifest", "trees", "signature")
               if k not in parts]
    if missing:
        raise RegistryMissError(f"incomplete parts, missing {missing}")
    try:
        manifest = msgpack.unpackb(parts["manifest"], raw=False)
        payload = b"".join(parts[k] for k in sorted(parts)
                           if k.startswith("payload/"))
        rec = Recording(manifest, payload, parts["trees"],
                        parts["signature"].decode())
    except Exception as e:
        raise TamperedRecordingError(f"unparseable registry parts: {e}")
    return rec.to_bytes()


class RegistryService:
    """Cloud registry front end over a ``RecordingStore``.

    ``record_profile`` selects the device<->cloud link a record-on-miss
    session runs over (None = in-process degenerate session): the paper's
    record phase is two-party, so a miss recorded for a wifi-attached
    device bills the distributed protocol's real round trips and bytes
    into the recording's manifest, and clients are charged that recorded
    cost on the cold fetch.
    """

    def __init__(self, store: RecordingStore, *, signing_key: bytes,
                 record_profile=None, record_passes="all", tracer=None,
                 keys: Optional[KeySchedule] = None):
        self._store = store
        self._key = signing_key
        self._record_profile = record_profile
        self._record_passes = record_passes
        self.tracer = tracer if tracer is not None else NULL
        self._delta: Dict[str, DeltaSync] = {}
        self._lock = threading.Lock()
        self._leases: Dict[str, threading.Event] = {}
        self.stats = collections.Counter()
        # transparency log over the index: one leaf per publish, heads
        # signed by the epoch key schedule (shared with clients through
        # the Workspace; a bare service derives one from the signing key
        # so directly-built service/client pairs agree at epoch 0)
        self.keys = keys if keys is not None else KeySchedule(signing_key)
        self.log = TransparencyLog()
        self._log_index: Dict[str, int] = {}    # key -> latest leaf index
        self._bootstrap_log()

    # --------------------------------------------------- transparency log --
    def _leaf_of(self, key: str, rec: Recording) -> dict:
        """The log leaf a publish of ``rec`` under ``key`` commits to.
        ``payload_digest`` doubles as the recording's executable
        fingerprint, so an offline verifier can bind a replay quote to
        this leaf without ever seeing the payload."""
        return {"key": key, "manifest_fp": fingerprint(rec.manifest),
                "payload_digest": fingerprint(rec.payload),
                "epoch": self.keys.epoch}

    def _append_leaf(self, leaf: dict) -> int:
        idx = self.log.append(leaf_data(leaf["key"], leaf["manifest_fp"],
                                        leaf["payload_digest"],
                                        leaf["epoch"]))
        self._log_index[leaf["key"]] = idx
        self.stats["log_appends"] += 1
        return idx

    def _bootstrap_log(self) -> None:
        """Rebuild the log view from a pre-populated store (a fresh
        service handle over an existing root): every entry's stored leaf
        re-appends in its original publish order, so proofs keep working
        across process restarts.  Clients pinned to heads of the ORIGINAL
        process only see consistent extensions as long as the rebuilt
        prefix matches — which it does when the store kept every key's
        latest leaf in index order."""
        rows = []
        for key in self._store.keys():
            att = (self._store.entry(key).get("meta") or {}).get("attest")
            if att:
                rows.append((int(att.get("index", 0)), att["leaf"]))
        for _idx, leaf in sorted(rows, key=lambda r: (r[0], r[1]["key"])):
            self._append_leaf(leaf)

    def _adopt(self, key: str) -> int:
        """Fold a key published through ANOTHER service handle on the
        shared store into this handle's log (read-modify-write stores
        merge entries across handles; the log view follows)."""
        att = (self._store.entry(key).get("meta") or {}).get("attest")
        if not att:
            raise AttestationError(
                f"'{key}' is in the store but was never published through "
                "the transparency log — refusing to serve a proof for it")
        return self._append_leaf(att["leaf"])

    def signed_head(self) -> dict:
        """The current signed tree head: ``{size, root, epoch,
        signature}``, signature epoch-bound under the key schedule."""
        size, root = self.log.size, self.log.root()
        return {"size": size, "root": root, "epoch": self.keys.epoch,
                "signature": self.keys.sign(
                    head_signable({"size": size, "root": root}))}

    def proof_for(self, key: str) -> dict:
        """Inclusion-proof bundle for ``key``'s latest published leaf
        against the current signed head: ``{key, leaf, index, head,
        path}``.  Served on every verified fetch."""
        if key not in self._log_index:
            self._adopt(key)
        idx = self._log_index[key]
        head = self.signed_head()
        self.stats["proofs_served"] += 1
        return {"key": key, "leaf": dict(self.log_leaf(idx)), "index": idx,
                "head": head,
                "path": self.log.inclusion_proof(idx, head["size"])}

    def log_leaf(self, index: int) -> dict:
        """Decode the raw leaf at ``index`` back into its field dict."""
        return json.loads(self.log.entries[index].decode())

    def consistency_between(self, old_size: int, new_size: int) -> dict:
        """Consistency proof between two signed tree sizes (clients call
        this with their pinned head's size on every later fetch)."""
        self.stats["consistency_proofs_served"] += 1
        return {"old_size": old_size, "new_size": new_size,
                "proof": self.log.consistency_proof(old_size, new_size)}

    def _run_record_fn(self, record_fn: Callable) -> Recording:
        """Run a record-on-miss through a ``RecordingSession`` when the
        callable accepts one (the CODY two-party record over the
        configured link); zero-arg record_fns keep working and record
        through the in-process degenerate session themselves."""
        try:
            takes_session = "session" in \
                inspect.signature(record_fn).parameters
        except (TypeError, ValueError):
            takes_session = False
        if not takes_session:
            return record_fn()
        from repro.record import RecordingSession
        if self._record_profile is not None:
            session = RecordingSession.for_profile(
                self._record_profile, passes=self._record_passes,
                tracer=self.tracer)
        else:
            session = RecordingSession.local(passes=self._record_passes,
                                             tracer=self.tracer)
        with traced(self.tracer, "registry.record_session", "registry",
                    passes=str(self._record_passes)):
            rec = record_fn(session=session)
        self.stats["record_virtual_s"] += \
            session.report()["virtual_time_s"]
        return rec

    # ------------------------------------------------------------ publish --
    def publish(self, key: str, rec: Recording) -> dict:
        """Publish a SIGNED recording under ``key``; returns wire stats.
        ``wire_bytes`` is what a delta upload ships (DeltaSync: only parts
        whose digest changed since the last version of this key);
        ``full_bytes`` is the naive full publish."""
        if not rec.signature:
            raise ValueError("publish requires a signed recording "
                             "(call rec.sign_with(key) first)")
        if not verify(rec.signable(), rec.signature, self._key):
            raise TamperedRecordingError(
                f"refusing to publish '{key}': signature does not verify "
                "under the registry key")
        parts = recording_to_parts(rec, self._store.chunk_size)
        ds = self._delta.setdefault(key, DeltaSync())
        sent_before = ds.stats["leaves_sent"]
        with traced(self.tracer, "registry.publish", "registry", key=key):
            wire = ds.pack({p: np.frombuffer(b, np.uint8) for p, b in
                            parts.items()})
        # transparency-log leaf: committed to the tree AND stored in the
        # entry meta, so a fresh service handle over this store rebuilds
        # the same log (and a store-level swap that bypasses publish()
        # leaves the log pointing at the ORIGINAL bytes — exactly what
        # clients detect as a silent swap)
        leaf = self._leaf_of(key, rec)
        entry = self._store.put(key, parts, meta={
            "attest": {"leaf": leaf, "index": self.log.size},
            "name": rec.manifest.get("name", key),
            "static": rec.manifest.get("static", {}),
            # identity fields clients filter alternates by: a recording is
            # only substitutable on matching hardware and model config
            "topology": rec.manifest.get("topology", ""),
            "config_fingerprint": rec.manifest.get("config_fingerprint", ""),
            "record_wall_s": rec.manifest.get("record_wall_s", 0.0),
            # distributed-session record cost (zero for local records):
            # what a cold record-on-miss fetch bills on top of wall time
            "record_virtual_s": rec.manifest.get("record_virtual_s", 0.0),
            "published_s": time.time()})
        idx = self._append_leaf(leaf)
        self.stats["publishes"] += 1
        return {"key": key, "version": entry["version"],
                "full_bytes": sum(len(b) for b in parts.values()),
                "wire_bytes": len(wire),
                "parts_sent": ds.stats["leaves_sent"] - sent_before,
                "chunks_new": entry["chunks_new"],
                "chunks_reused": entry["chunks_reused"],
                "log_index": idx, "log_size": self.log.size,
                "root": self.log.root()}

    # -------------------------------------------------------------- fetch --
    def fetch_bytes(self, key: str) -> bytes:
        self.stats["fetches"] += 1
        return parts_to_recording_bytes(self._store.get(key))

    def ensure(self, key: str,
               record_fn: Optional[Callable[[], Recording]] = None) -> None:
        """Make ``key`` present, running ``record_fn`` under a
        single-flight lease on miss: concurrent missers block on the
        leaseholder's event and reuse the published result — exactly one
        record() per key no matter how many clients race.  Does NOT
        reassemble the recording (clients pull chunks themselves)."""
        with self._lock:
            # only the hit/lease decision happens under the global lock;
            # publishing/fetching must not serialize unrelated clients —
            # the store has its own lock
            if self._store.has(key):
                self.stats["hits"] += 1
                return
            lease = self._leases.get(key)
            if lease is None:
                lease = self._leases[key] = threading.Event()
                owner = True
            else:
                owner = False
        if not owner:
            self.stats["lease_waits"] += 1
            if self.tracer:
                self.tracer.instant("registry.lease_wait", "registry",
                                    key=key)
            lease.wait()
            if not self._store.has(key):
                raise RegistryMissError(
                    f"record-on-miss for '{key}' failed on the leaseholder")
            return
        try:
            if record_fn is None:
                raise RegistryMissError(
                    f"'{key}' not in registry and no record_fn provided")
            rec = self._run_record_fn(record_fn)
            if not rec.signature:
                rec.sign_with(self._key)
            self.stats["records"] += 1
            self.publish(key, rec)
        finally:
            with self._lock:
                self._leases.pop(key, None)
            lease.set()

    def get_or_record(self, key: str,
                      record_fn: Optional[Callable[[], Recording]] = None
                      ) -> bytes:
        self.ensure(key, record_fn)
        return self.fetch_bytes(key)

    # ------------------------------------------------- multi-variant lease --
    def variant_lease(self, group: str, keys) -> "VariantLeaseSet":
        """Multi-variant lease fan-out for recording campaigns.

        ``ensure()`` single-flights ONE key: N missers of the same key
        produce one record.  A campaign populating a key's shape variants
        wants the dual: N workers each claim a DIFFERENT variant and
        record concurrently.  The returned set's ``claim(key)`` takes the
        per-key lease under the same ``self._leases`` table ``ensure()``
        uses, so a plain client missing on a variant mid-campaign becomes
        a waiter on the campaign worker's lease — the two mechanisms
        compose instead of racing."""
        self.stats["variant_lease_groups"] += 1
        return VariantLeaseSet(self, group, list(keys))

    # ------------------------------------------------- store passthroughs --
    @property
    def chunk_size(self) -> int:
        return self._store.chunk_size

    def has(self, key: str) -> bool:
        return self._store.has(key)

    def entry(self, key: str) -> dict:
        return self._store.entry(key)

    def find(self, prefix: str):
        return self._store.find(prefix)

    def read_chunk(self, digest: str) -> bytes:
        return self._store.read_chunk(digest)


class VariantLeaseSet:
    """A campaign's claims over one key-group's variants.

    Each ``claim(key)`` either takes that key's single-flight lease (the
    SAME per-key ``threading.Event`` table ``RegistryService.ensure``
    blocks on, so concurrent plain missers become waiters of the
    campaign worker) or reports why not: ``"published"`` (someone already
    has it) / ``"leased"`` (another worker is recording it right now).
    ``complete(key, rec)`` publishes and releases; ``fail(key)`` releases
    without publishing, waking waiters into their own miss handling."""

    def __init__(self, service: RegistryService, group: str,
                 keys: list):
        self.service = service
        self.group = group
        self.keys = keys
        self.owned: set = set()
        self.stats = collections.Counter()

    def claim(self, key: str) -> Optional[str]:
        """Try to take ``key``'s lease.  Returns None on success, else
        the skip reason ("published" / "leased")."""
        svc = self.service
        with svc._lock:
            if svc._store.has(key):
                svc.stats["hits"] += 1
                self.stats["skipped_published"] += 1
                return "published"
            if key in svc._leases:
                self.stats["skipped_leased"] += 1
                return "leased"
            svc._leases[key] = threading.Event()
            self.owned.add(key)
        svc.stats["variant_claims"] += 1
        self.stats["claims"] += 1
        if svc.tracer:
            svc.tracer.instant("registry.variant_claim", "registry",
                               group=self.group, key=key)
        return None

    def _release(self, key: str) -> None:
        svc = self.service
        with svc._lock:
            lease = svc._leases.pop(key, None)
        self.owned.discard(key)
        if lease is not None:
            lease.set()

    def complete(self, key: str, rec: Recording) -> dict:
        """Publish the finished variant (delta-packed per key) and wake
        its waiters.  The lease is released even if publish raises —
        waiters then re-check the store and surface the miss themselves,
        exactly as ``ensure()``'s failure path behaves."""
        if key not in self.owned:
            raise KeyError(f"variant '{key}' is not leased by this "
                           f"campaign ('{self.group}')")
        try:
            if not rec.signature:
                rec.sign_with(self.service._key)
            out = self.service.publish(key, rec)
            self.service.stats["records"] += 1
            self.stats["completed"] += 1
            return out
        finally:
            self._release(key)

    def fail(self, key: str) -> None:
        """Give up a claimed variant without publishing (no-op for keys
        this set does not own)."""
        if key in self.owned:
            self.stats["failed"] += 1
            self._release(key)

    def outstanding(self) -> set:
        return set(self.owned)
