"""Recording registry: content-addressed store + collaborative
record-on-miss service + netem-billed device client.

Recordings are produced once in the trusted cloud (CODY §3) and replayed
by fleets of clients; this package is the distribution layer between
``recorder.record()`` and ``Replayer.load``:

    store.py    content-addressed chunk store, signed index, LRU cache, GC
    service.py  fetch-by-key, single-flight record-on-miss, delta publish
    client.py   chunked resumable fetch over NetworkEmulator, verify-then-
                replay handoff into Replayer/Engine
    replica.py  regional read-replicas with chunk caches (CDN fan-out)

``key_for`` is THE recording identity: record, serve, and the replayer's
executable cache all key by it (one helper instead of three ad-hoc
naming schemes).
"""
from __future__ import annotations

from repro.core.attest import SplitViewError, fingerprint
from repro.registry.client import FetchInterrupted, RegistryClient
from repro.registry.replica import RegistryReadReplica
from repro.registry.service import (RegistryService, VariantLeaseSet,
                                    parts_to_recording_bytes,
                                    recording_to_parts)
from repro.registry.store import (LRUBytes, RecordingStore,
                                  RegistryIntegrityError, RegistryMissError)


def key_arch(arch: str) -> str:
    """Canonical architecture id.  Smoke-shrunk configs record AND replay
    under the base arch name (both sides shrink identically), so the
    ``-smoke`` suffix is identity-irrelevant and stripped here — this is
    the one place that normalization lives."""
    return arch[:-len("-smoke")] if arch.endswith("-smoke") else arch


def key_for(arch: str, kind: str, shapes, mesh_fp: str) -> str:
    """Canonical registry key for a recording: one key scheme shared by
    the record CLI (publish), the serve CLI (fetch), and the replayer's
    executable cache (load name).

    ``shapes`` is any JSON-serializable description of the recorded
    shapes/static config (e.g. the record CLI's static_meta dict);
    ``mesh_fp`` fingerprints the mesh the executable was compiled for.
    """
    return f"{key_arch(arch)}/{kind}/{fingerprint(shapes, mesh_fp)[:16]}"


__all__ = [
    "FetchInterrupted", "LRUBytes", "RecordingStore", "RegistryClient",
    "RegistryIntegrityError", "RegistryMissError", "RegistryReadReplica",
    "RegistryService", "SplitViewError", "VariantLeaseSet", "key_arch",
    "key_for", "parts_to_recording_bytes", "recording_to_parts",
]
