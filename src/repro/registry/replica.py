"""Registry read-replicas — CDN-style regional fan-out for recordings.

A ``RegistryReadReplica`` fronts a primary ``RegistryService`` with a
regional chunk cache: the first fetch of a popular key in a region pulls
its chunks from the primary ONCE, every later fetch in that region is
served from the regional ``LRUBytes`` — the primary's
``stats['chunk_reads']`` stays flat no matter how many replicas boot
(the read-replica effectiveness test pins exactly that).

It duck-types the client-facing surface of ``RegistryService``
(``chunk_size`` / ``has`` / ``entry`` / ``find`` / ``ensure`` /
``read_chunk``), so a ``RegistryClient`` built against it needs no code
changes; writes (``ensure`` record-on-miss leases) pass through to the
primary — read-replicas replicate reads, never take leases themselves.

Integrity is unchanged: a regionally cached chunk is re-verified against
its content address on every hit (same rule as the store), and clients
still HMAC-verify the assembled recording before unpickling — a
compromised regional cache can only cause a detected integrity error,
never bad replay bytes.
"""
from __future__ import annotations

import collections
from typing import Optional

from repro.attest.log import verify_consistency
from repro.core.attest import SplitViewError
from repro.registry.service import RegistryService
from repro.registry.store import (LRUBytes, RegistryIntegrityError,
                                  chunk_digest)


class RegistryReadReplica:
    """One region's read path onto a primary registry service."""

    def __init__(self, primary: RegistryService, *, region: str,
                 cache_bytes: int = 32 << 20, metrics=None):
        self._primary = primary
        self.region = region
        self.cache = LRUBytes(cache_bytes, metrics=metrics, region=region)
        self.stats = collections.Counter()
        self._sth: Optional[dict] = None    # region-pinned {size, root}

    # ----------------------------------------------- read-path overrides --
    def read_chunk(self, digest: str) -> bytes:
        hit = self.cache.get(digest)
        if hit is not None:
            if chunk_digest(hit) != digest:    # re-verify EVERY read
                raise RegistryIntegrityError(
                    f"regional chunk {digest[:12]}... corrupted in "
                    f"'{self.region}' cache")
            return hit
        raw = self._primary.read_chunk(digest)
        self.cache.put(digest, raw)
        self.stats["chunk_pulls"] += 1
        self.stats["chunk_pull_bytes"] += len(raw)
        return raw

    # ------------------------------------------------ primary passthrough --
    @property
    def chunk_size(self) -> int:
        return self._primary.chunk_size

    def has(self, key: str) -> bool:
        return self._primary.has(key)

    def entry(self, key: str) -> dict:
        return self._primary.entry(key)

    def find(self, prefix: str):
        return self._primary.find(prefix)

    def ensure(self, key: str, record_fn=None) -> None:
        # record-on-miss is a WRITE: it goes to the primary's single-flight
        # lease; the resulting chunks then replicate here on first read
        self.stats["ensure_passthrough"] += 1
        return self._primary.ensure(key, record_fn)

    # --------------------------------------------------- transparency log --
    @property
    def keys(self):
        return self._primary.keys

    def proof_for(self, key: str) -> dict:
        """Relay the primary's proof bundle, CROSS-CHECKING it against the
        replica's own pinned tree head first: a primary that shows one
        log to region A and another to region B (a split view across
        regions) is caught at the replica, before any client in the
        region sees the forked head."""
        bundle = self._primary.proof_for(key)
        head = bundle["head"]
        if self._sth is not None and self._sth["size"] > 0:
            old_size, old_root = self._sth["size"], self._sth["root"]
            if head["size"] < old_size:
                raise SplitViewError(
                    f"primary log shrank ({old_size} -> {head['size']}) "
                    f"behind region '{self.region}'")
            cp = self._primary.consistency_between(old_size, head["size"])
            if not verify_consistency(old_size, old_root, head["size"],
                                      head["root"], cp["proof"]):
                raise SplitViewError(
                    f"primary served region '{self.region}' a forked log: "
                    f"consistency {old_size} -> {head['size']} failed")
        self._sth = {"size": head["size"], "root": head["root"]}
        self.stats["proofs_relayed"] += 1
        return bundle

    def consistency_between(self, old_size: int, new_size: int) -> dict:
        return self._primary.consistency_between(old_size, new_size)

    # ---------------------------------------------------------- reporting --
    def summary(self) -> dict:
        return {"region": self.region,
                "chunk_pulls": int(self.stats["chunk_pulls"]),
                "chunk_pull_bytes": int(self.stats["chunk_pull_bytes"]),
                "ensure_passthrough": int(self.stats["ensure_passthrough"]),
                "proofs_relayed": int(self.stats["proofs_relayed"]),
                "cache": self.cache.summary()}


__all__ = ["RegistryReadReplica"]
