"""Content-addressed recording store — the registry's durable format.

A recording is stored as *parts* (named byte sections: manifest, payload
chunks, trees, signature), each split at ``chunk_size`` and addressed by
the SHA-256 of its raw bytes.  Chunks are zlib-compressed at rest and
deduplicated across recordings and versions: re-publishing a recording
whose payload did not change writes no new payload chunks, which is what
makes delta publishing (service.py) and delta fetching (client.py) cheap.

The index (registry key -> ordered chunk list + metadata) is HMAC-signed
with the registry key; the signature and every chunk digest are
re-verified on EVERY read — a flipped bit anywhere in the store surfaces
as ``RegistryIntegrityError`` (a ``TamperedRecordingError``), never as
silently corrupt replay bytes.

Backends: in-memory (``root=None``, used by benchmarks/tests) or a
filesystem directory (``root=path``: ``chunks/<aa>/<digest>`` +
``index.msgpack``), suitable as an on-disk registry mirror.
"""
from __future__ import annotations

import collections
import hashlib
import os
import threading
import time
import zlib
from typing import Dict, Iterable, List, Optional

import msgpack

from repro.core.attest import TamperedRecordingError, sign, verify

CHUNK_SIZE = 64 * 1024
_INDEX_FILE = "index.msgpack"


class RegistryIntegrityError(TamperedRecordingError):
    """Store content does not match its digests / index signature."""


class RegistryMissError(KeyError):
    """No recording published under this registry key."""


class LRUBytes:
    """Byte-budgeted LRU map of chunk digest -> raw chunk bytes.  Used as
    the client-side chunk cache (bounded so a device never holds more than
    ``max_bytes`` of recording chunks) and as the regional chunk cache of
    registry read-replicas.

    When a ``repro.obs.metrics.Metrics`` registry is attached, every
    hit/miss/eviction also increments ``registry_cache_{hits,misses,
    evictions}`` counters under the given labels (e.g. ``scope="store"``
    or ``region="eu"``), so cache effectiveness is observable fleet-wide
    without reaching into each cache's local counter."""

    def __init__(self, max_bytes: int, *, metrics=None, **labels):
        self.max_bytes = max_bytes
        self._d: "collections.OrderedDict[str, bytes]" = \
            collections.OrderedDict()
        self.nbytes = 0
        self.stats = collections.Counter()
        self._metrics = metrics
        self._labels = labels

    def _count(self, event: str):
        self.stats[event] += 1
        if self._metrics is not None:
            self._metrics.counter(f"registry_cache_{event}",
                                  **self._labels).inc()

    def get(self, digest: str) -> Optional[bytes]:
        blob = self._d.get(digest)
        if blob is None:
            self._count("misses")
            return None
        self._d.move_to_end(digest)
        self._count("hits")
        return blob

    def put(self, digest: str, blob: bytes):
        if digest in self._d:
            self._d.move_to_end(digest)
            return
        self._d[digest] = blob
        self.nbytes += len(blob)
        while self.nbytes > self.max_bytes and len(self._d) > 1:
            _old, dropped = self._d.popitem(last=False)
            self.nbytes -= len(dropped)
            self._count("evictions")

    def summary(self) -> dict:
        """Pinned cache accounting for reports: budget, occupancy, and
        the hit/miss/eviction counters."""
        return {"max_bytes": self.max_bytes, "nbytes": self.nbytes,
                "entries": len(self._d),
                "hits": int(self.stats["hits"]),
                "misses": int(self.stats["misses"]),
                "evictions": int(self.stats["evictions"])}

    def __contains__(self, digest: str) -> bool:
        return digest in self._d

    def __len__(self) -> int:
        return len(self._d)


def chunk_digest(raw: bytes) -> str:
    return hashlib.sha256(raw).hexdigest()


def split_chunks(blob: bytes, chunk_size: int) -> List[bytes]:
    if not blob:
        return [b""]
    return [blob[i:i + chunk_size] for i in range(0, len(blob), chunk_size)]


class RecordingStore:
    """Chunked, deduplicated, integrity-checked map of
    registry key -> {part name -> bytes}."""

    def __init__(self, root: Optional[str] = None, *, key: bytes,
                 chunk_size: int = CHUNK_SIZE, cache_bytes: int = 0,
                 metrics=None):
        self._root = root
        self._key = key
        self.chunk_size = chunk_size
        self._lock = threading.Lock()
        self.cache = LRUBytes(cache_bytes, metrics=metrics,
                              scope="store") if cache_bytes > 0 else None
        self.stats = collections.Counter()
        self._mem_chunks: Dict[str, bytes] = {}
        self._entries: Dict[str, dict] = {}
        self._index_sig = ""
        self._index_mtime = None
        if root is not None:
            os.makedirs(os.path.join(root, "chunks"), exist_ok=True)
            self._load_index()
        if self._index_mtime is None:
            # no index on disk (or in-memory backend): create a fresh
            # signed one.  Opening an EXISTING root is a read, not a
            # mutation — rewriting here would clobber entries another
            # process published since our snapshot.
            self._resign_index()

    # ----------------------------------------------------------- index ----
    def _index_signable(self) -> bytes:
        return msgpack.packb(
            sorted(self._entries.items()), use_bin_type=True)

    def _resign_index(self):
        self._index_sig = sign(self._index_signable(), self._key)
        if self._root is not None:
            blob = msgpack.packb(
                {"entries": self._entries, "signature": self._index_sig},
                use_bin_type=True)
            tmp = os.path.join(self._root, _INDEX_FILE + ".tmp")
            with open(tmp, "wb") as f:
                f.write(blob)
            path = os.path.join(self._root, _INDEX_FILE)
            os.replace(tmp, path)
            self._index_mtime = os.stat(path).st_mtime_ns

    def _load_index(self):
        path = os.path.join(self._root, _INDEX_FILE)
        if not os.path.exists(path):
            return
        try:
            with open(path, "rb") as f:
                d = msgpack.unpackb(f.read(), raw=False)
            self._entries = d.get("entries", {})
            self._index_sig = d.get("signature", "")
        except Exception as e:   # corrupted framing == tampering
            raise RegistryIntegrityError(f"unparseable registry index: {e}")
        self._index_mtime = os.stat(path).st_mtime_ns
        self._check_index()

    def _maybe_reload(self):
        """Pick up index changes another process wrote to a shared root
        (e.g. the record CLI publishing while a serve process holds the
        registry open).  Callers hold ``self._lock``.  This makes
        read-modify-write the rule for mutations, not last-writer-wins;
        truly simultaneous writers would still need file locking."""
        if self._root is None:
            return
        path = os.path.join(self._root, _INDEX_FILE)
        try:
            mtime = os.stat(path).st_mtime_ns
        except FileNotFoundError:
            return
        if mtime != self._index_mtime:
            self._load_index()

    def _check_index(self):
        if not verify(self._index_signable(), self._index_sig, self._key):
            raise RegistryIntegrityError("registry index signature invalid")

    # ---------------------------------------------------------- chunk IO ----
    def _chunk_path(self, digest: str) -> str:
        return os.path.join(self._root, "chunks", digest[:2], digest)

    def _write_chunk(self, digest: str, raw: bytes) -> int:
        """Store one chunk (zlib at rest); returns compressed size."""
        comp = zlib.compress(raw, 6)
        if self._root is None:
            self._mem_chunks[digest] = comp
        else:
            path = self._chunk_path(digest)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(comp)
            os.replace(tmp, path)
        return len(comp)

    def _has_chunk(self, digest: str) -> bool:
        if self._root is None:
            return digest in self._mem_chunks
        return os.path.exists(self._chunk_path(digest))

    def _stored_chunk_len(self, digest: str) -> int:
        """Compressed size of an already-stored chunk — the dedup path
        must not recompress just to learn the length."""
        if self._root is None:
            return len(self._mem_chunks[digest])
        return os.path.getsize(self._chunk_path(digest))

    def read_chunk(self, digest: str) -> bytes:
        """Fetch + decompress + RE-VERIFY one chunk (every read, not just
        the first: at-rest corruption must never reach the replayer)."""
        if self.cache is not None:
            hit = self.cache.get(digest)
            if hit is not None:
                if chunk_digest(hit) != digest:   # re-verify EVERY read
                    raise RegistryIntegrityError(
                        f"cached chunk {digest[:12]}... corrupted in memory")
                return hit
        if self._root is None:
            comp = self._mem_chunks.get(digest)
            if comp is None:
                raise RegistryMissError(f"chunk {digest[:12]}... not in store")
        else:
            path = self._chunk_path(digest)
            if not os.path.exists(path):
                raise RegistryMissError(f"chunk {digest[:12]}... not in store")
            with open(path, "rb") as f:
                comp = f.read()
        try:
            raw = zlib.decompress(comp)
        except zlib.error as e:
            raise RegistryIntegrityError(
                f"chunk {digest[:12]}... undecompressable: {e}")
        if chunk_digest(raw) != digest:
            raise RegistryIntegrityError(
                f"chunk {digest[:12]}... content does not match its address")
        if self.cache is not None:
            self.cache.put(digest, raw)
        self.stats["chunk_reads"] += 1
        return raw

    # ------------------------------------------------------------ public ----
    def put(self, key: str, parts: Dict[str, bytes],
            meta: Optional[dict] = None) -> dict:
        """Publish (or re-publish) a recording's parts under ``key``.
        Unchanged chunks are deduplicated by content address; the index
        entry is replaced and the version bumped."""
        with self._lock:
            self._maybe_reload()
            chunks, new, reused, total = [], 0, 0, 0
            for part, blob in parts.items():
                for seq, raw in enumerate(split_chunks(blob, self.chunk_size)):
                    d = chunk_digest(raw)
                    if self._has_chunk(d):
                        reused += 1
                        comp_len = self._stored_chunk_len(d)
                    else:
                        comp_len = self._write_chunk(d, raw)
                        new += 1
                    chunks.append({"part": part, "seq": seq, "d": d,
                                   "n": len(raw), "c": comp_len})
                    total += len(raw)
            prev = self._entries.get(key)
            entry = {"version": (prev["version"] + 1) if prev else 1,
                     "total": total, "chunks": chunks, "meta": meta or {}}
            self._entries[key] = entry
            self._resign_index()
            self.stats["puts"] += 1
            return {**entry, "chunks_new": new, "chunks_reused": reused}

    def entry(self, key: str) -> dict:
        with self._lock:
            self._maybe_reload()
            self._check_index()
            if key not in self._entries:
                raise RegistryMissError(key)
            return self._entries[key]

    def get(self, key: str) -> Dict[str, bytes]:
        """Reassemble all parts of ``key``, verifying the index signature
        and every chunk digest.  Chunks are read outside the lock, so a
        concurrent re-publish + gc can invalidate our entry snapshot
        mid-read — in that case the key is still live under a NEW entry,
        and one retry against the fresh snapshot resolves it."""
        for attempt in (0, 1):
            entry = self.entry(key)
            parts: Dict[str, List[bytes]] = {}
            try:
                for c in entry["chunks"]:
                    raw = self.read_chunk(c["d"])
                    if len(raw) != c["n"]:
                        raise RegistryIntegrityError(
                            f"chunk {c['d'][:12]}... length {len(raw)} != "
                            f"indexed {c['n']}")
                    parts.setdefault(c["part"], []).append(raw)
            except RegistryMissError:
                if attempt:
                    raise
                continue
            self.stats["gets"] += 1
            return {part: b"".join(pieces) for part, pieces in parts.items()}

    def summary(self) -> dict:
        """Store accounting for ``Workspace.report()``: operation counters
        plus the LRU chunk-cache summary (None when the cache is off)."""
        return {"chunk_reads": int(self.stats["chunk_reads"]),
                "puts": int(self.stats["puts"]),
                "gets": int(self.stats["gets"]),
                "cache": self.cache.summary()
                if self.cache is not None else None}

    def has(self, key: str) -> bool:
        with self._lock:
            self._maybe_reload()
            return key in self._entries

    def find(self, prefix: str) -> List[str]:
        """Keys under a key prefix (e.g. ``"qwen2.5-3b/decode/"``)."""
        with self._lock:
            self._maybe_reload()
            return sorted(k for k in self._entries if k.startswith(prefix))

    def keys(self) -> List[str]:
        with self._lock:
            self._maybe_reload()
            return sorted(self._entries)

    def delete(self, key: str):
        with self._lock:
            self._maybe_reload()
            self._entries.pop(key, None)
            self._resign_index()

    def _referenced(self) -> set:
        return {c["d"] for e in self._entries.values() for c in e["chunks"]}

    def referenced_digests(self) -> Iterable[str]:
        with self._lock:
            return self._referenced()

    GC_TMP_AGE_S = 300   # in-flight .tmp files younger than this survive

    def gc(self) -> int:
        """Remove chunks referenced by no index entry (e.g. after a
        re-publish replaced them or a key was deleted).  The live set is
        computed under the same lock as the deletions, so an in-process
        concurrent put() can never have its freshly indexed chunks
        collected.  The lock is per-process: on a SHARED root, run gc
        from the publishing/admin role only — stale ``.tmp`` files are
        aged before removal so another process's in-flight chunk write is
        not broken, but a publisher whose chunks land before its index
        write could still race a foreign gc."""
        removed = 0
        now = time.time()
        with self._lock:
            self._maybe_reload()
            live = self._referenced()
            if self._root is None:
                for d in [d for d in self._mem_chunks if d not in live]:
                    del self._mem_chunks[d]
                    removed += 1
            else:
                cdir = os.path.join(self._root, "chunks")
                for sub in os.listdir(cdir):
                    subdir = os.path.join(cdir, sub)
                    for d in os.listdir(subdir):
                        path = os.path.join(subdir, d)
                        if d.endswith(".tmp"):
                            # only collect ABANDONED temp files; a young
                            # one is another process mid-_write_chunk
                            try:
                                if now - os.path.getmtime(path) > \
                                        self.GC_TMP_AGE_S:
                                    os.remove(path)
                                    removed += 1
                            except FileNotFoundError:
                                pass
                        elif d not in live:
                            os.remove(path)
                            removed += 1
            self.stats["gc_removed"] += removed
        return removed
