"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Params and activations are annotated with *logical* axis names; rules resolve
them to physical mesh axes per execution mode.  This keeps model code mesh-
agnostic (the paper's "record with the exact hardware" requirement becomes:
recordings embed the resolved mesh; replay validates the fingerprint).

Modes
-----
train:  batch/fsdp -> ('pod','data');  heads/ffn/vocab/experts -> 'model'
        (2D weight sharding: FSDP over the data axes + TP over model — ZeRO-1
        optimizer state is sharded the same way.)
serve:  TP-dominant — weights sharded over 'model' only (no per-step weight
        all-gathers on the latency path); KV cache sequence-sharded over
        'model' (sequence parallelism) so GQA archs with few KV heads still
        scale to TP=16; MoE expert weights additionally sharded over the data
        axes on d_model (2D weight-stationary) so 8x22B fits.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXES = ("pod", "data")  # flattened DP axes (pod may be absent)


def _dp(mesh_axes: Tuple[str, ...]):
    present = tuple(a for a in DATA_AXES if a in mesh_axes)
    return present if len(present) > 1 else (present[0] if present else None)


def rules_for(mode: str, mesh_axes: Tuple[str, ...], fsdp: bool = True) -> dict:
    dp = _dp(mesh_axes)
    tp = "model" if "model" in mesh_axes else None
    common = {
        "batch": dp, "seq": None, "embed": None, "heads": tp, "kv_heads": tp,
        "head_dim": None, "ffn": tp, "vocab": tp, "experts": tp,
        "expert_ffn": tp, "kv_lora": None, "ssm_inner": tp, "ssm_heads": tp,
        "ssm_state": None, "layers": None, "conv": None, "norm": None,
        "stack": None,
    }
    if mode == "train":
        common["fsdp"] = dp if fsdp else None      # 2nd weight dim
        common["seq"] = tp                         # Megatron-style SP: the
        # residual stream between blocks is sequence-sharded; attention/MLP
        # internals are head/ffn-sharded (XLA inserts the AG/RS pairs).
        # Cuts saved-activation memory by TP degree at equal collective cost
        # to pure-TP's per-layer all-reduces.
        common["kv_seq"] = None                    # KV == activations in train
        common["expert_embed"] = dp                # MoE 2D weight sharding
    elif mode == "train_zero":
        # ZeRO-3 pure data parallelism: every mesh axis is batch DP; weights
        # (and optimizer state) are sharded over ALL axes and gathered per
        # layer.  No activation collectives at all — the right schedule when
        # per-layer weight bytes << per-layer activation bytes (narrow
        # models / large batches).  Hillclimbed in EXPERIMENTS.md §Perf.
        allaxes = tuple(a for a in ("pod", "data", "model") if a in mesh_axes)
        common.update({
            "batch": allaxes, "seq": None, "heads": None, "kv_heads": None,
            "head_dim": None, "ffn": None, "expert_ffn": None,
            "ssm_inner": None, "ssm_heads": None,
            "fsdp": allaxes, "expert_embed": allaxes, "kv_seq": None,
        })
    elif mode == "serve":
        common["fsdp"] = None                      # no weight gathers at decode
        common["kv_seq"] = tp                      # SP: cache seq over model
        common["expert_embed"] = dp                # MoE 2D weight-stationary
    else:
        raise ValueError(f"unknown mode {mode}")
    return common


def spec(axes: Tuple[Optional[str], ...], rules: dict,
         shape: Optional[Tuple[int, ...]] = None,
         mesh_shape: Optional[dict] = None) -> P:
    """Resolve logical axes -> PartitionSpec.

    With ``shape``/``mesh_shape``, any dim whose size is not divisible by
    the mapped mesh-axis product falls back to replication (e.g. kv_heads=2
    cannot shard over model=16; starcoder's 36 q-heads likewise)."""
    parts, used = [], set()
    for i, a in enumerate(axes):
        if a is None:
            parts.append(None)
            continue
        phys = rules.get(a)
        # one physical axis may appear only once in a spec
        key = tuple(phys) if isinstance(phys, tuple) else (phys,)
        if phys is None or any(k in used for k in key):
            parts.append(None)
            continue
        if shape is not None and mesh_shape is not None:
            nshard = 1
            for k in key:
                nshard *= mesh_shape.get(k, 1)
            # prefix fallback: drop trailing axes of a tuple mapping until
            # the dim divides (e.g. batch 256 on ("pod","data","model")=512
            # -> ("pod","data")=32)
            while key and shape[i] % nshard:
                nshard //= mesh_shape.get(key[-1], 1)
                key = key[:-1]
            if not key or shape[i] % nshard:
                parts.append(None)
                continue
            phys = key if len(key) > 1 else key[0]
        used.update(key)
        parts.append(phys)
    return P(*parts)


def shardings_for(axes_tree, abstract_tree, mesh: Mesh, rules: dict):
    """Divisibility-checked NamedShardings for an abstract pytree."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    flat_ax = jax.tree.flatten(axes_tree, is_leaf=is_ax)[0]
    flat_ab, treedef = jax.tree.flatten(abstract_tree)
    assert len(flat_ax) == len(flat_ab), (len(flat_ax), len(flat_ab))
    out = [NamedSharding(mesh, spec(a, rules, v.shape, mesh_shape))
           for a, v in zip(flat_ax, flat_ab)]
    return jax.tree.unflatten(treedef, out)


def tree_specs(axes_tree, rules: dict):
    return jax.tree.map(
        lambda ax: spec(ax, rules), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(axes_tree, mesh: Mesh, rules: dict):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs(axes_tree, rules))


def constrain(x, axes: Tuple[Optional[str], ...], rules: dict):
    """with_sharding_constraint by logical axes (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec(axes, rules))
    except (ValueError, RuntimeError):
        return x
