"""Elastic scaling: restart on a different device count/mesh.

Shardings are logical rules resolved at record time; recordings embed the
mesh fingerprint.  On a topology change (node failure, scale-up):

  1. pick the new mesh from the surviving device count,
  2. restore the checkpoint (logical arrays) and device_put with the new
     mesh's shardings,
  3. re-record (re-compile) the step for the new mesh — the CODY recorder
     caches recordings per (workload, shape, mesh) fingerprint so repeated
     failovers to a known topology skip compilation entirely.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.sharding import rules_for, shardings_for


def choose_mesh_shape(n_devices: int, prefer_model: int = 16) -> Tuple[int, int]:
    """Largest (data, model) grid for the surviving devices; model axis
    capped at prefer_model and must divide n_devices."""
    model = min(prefer_model, n_devices)
    while n_devices % model:
        model -= 1
    return (n_devices // model, model)


def make_elastic_mesh(n_devices: Optional[int] = None, prefer_model: int = 16):
    devs = jax.devices()[:n_devices] if n_devices else jax.devices()
    shape = choose_mesh_shape(len(devs), prefer_model)
    import numpy as _np
    kw = {}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * 2
    return jax.sharding.Mesh(
        _np.asarray(devs).reshape(shape), ("data", "model"), **kw)


def reshard_state(state_np, axes_tree, mesh, mode: str = "train"):
    """device_put a restored (numpy) state onto a new mesh."""
    rules = rules_for(mode, mesh.axis_names)
    sh = shardings_for(axes_tree, state_np, mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state_np, sh)
