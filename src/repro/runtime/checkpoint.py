"""Fault-tolerant checkpointing: content-addressed chunks + metastate
manifest (the paper's metastate/program-data split applied to persistence).

* Program data (weights, moments) -> write-once chunks keyed by content
  hash: unchanged tensors across steps cost nothing (dedup), partial writes
  are harmless (manifest commits atomically last).
* Metastate (step, RNG, data cursor, slot tables) -> inline in the manifest
  via DeltaSync-compatible packing.
* Restore reshards to ANY mesh: chunks hold logical arrays; elastic
  restart = load + device_put with the new mesh's shardings (recordings are
  re-made per mesh fingerprint — paper §2.4's exact-hardware rule).
* ``async_save`` runs serialization off-thread; ``save`` is atomic via
  tempfile + rename.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import tempfile
import threading
from typing import Dict, Optional

import jax
import numpy as np

from repro.core import metasync


def _chunk_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


class CheckpointStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "chunks"), exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None
        self.stats = {"chunks_written": 0, "chunks_deduped": 0,
                      "bytes_written": 0}

    # ----------------------------------------------------------- writing --
    def _write_chunk(self, arr: np.ndarray) -> str:
        blob = _chunk_bytes(arr)
        h = hashlib.sha256(blob).hexdigest()[:32]
        path = os.path.join(self.root, "chunks", h + ".npy")
        if not os.path.exists(path):
            with tempfile.NamedTemporaryFile(
                    dir=os.path.dirname(path), delete=False) as f:
                f.write(blob)
            os.replace(f.name, path)
            self.stats["chunks_written"] += 1
            self.stats["bytes_written"] += len(blob)
        else:
            self.stats["chunks_deduped"] += 1
        return h

    def save(self, state, step: int, extra_meta: Optional[Dict] = None):
        """Blocking atomic save."""
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        meta, data = metasync.split(host_state)
        manifest = {
            "step": step,
            "meta": {p: {"data": _chunk_bytes(np.asarray(v)).hex()}
                     for p, v in meta.items()},
            "data": {},
            "extra": extra_meta or {},
        }
        for path, arr in data.items():
            h = self._write_chunk(np.asarray(arr))
            manifest["data"][path] = {
                "hash": h, "shape": list(np.asarray(arr).shape),
                "dtype": str(np.asarray(arr).dtype)}
        mpath = os.path.join(self.root, f"manifest_{step:08d}.json")
        with tempfile.NamedTemporaryFile("w", dir=self.root,
                                         delete=False) as f:
            json.dump(manifest, f)
        os.replace(f.name, mpath)   # atomic commit point
        return mpath

    def async_save(self, state, step: int, extra_meta=None):
        """Snapshot on the caller thread (cheap host copy), serialize on a
        background thread — training continues immediately."""
        host_state = jax.tree.map(lambda x: np.asarray(x).copy(), state)
        self.wait()
        t = threading.Thread(target=self.save,
                             args=(host_state, step, extra_meta))
        t.start()
        self._pending = t
        return t

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # ----------------------------------------------------------- reading --
    def latest_step(self) -> Optional[int]:
        steps = [int(f[len("manifest_"):-5]) for f in os.listdir(self.root)
                 if f.startswith("manifest_")]
        return max(steps) if steps else None

    def restore(self, state_like, step: Optional[int] = None):
        """Rebuild the state pytree (numpy leaves) from a manifest.

        ``state_like`` provides the pytree structure (abstract or concrete).
        Resharding to a new mesh is the caller's ``jax.device_put`` with the
        new shardings — chunks are logical arrays, so any mesh works."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint manifests in " + self.root)
        with open(os.path.join(self.root, f"manifest_{step:08d}.json")) as f:
            manifest = json.load(f)
        meta = {p: np.load(io.BytesIO(bytes.fromhex(d["data"])),
                           allow_pickle=False)
                for p, d in manifest["meta"].items()}
        data = {}
        for path, d in manifest["data"].items():
            with open(os.path.join(self.root, "chunks",
                                   d["hash"] + ".npy"), "rb") as f:
                data[path] = np.load(f, allow_pickle=False)
        return metasync.merge(state_like, meta, data), manifest

    def gc(self, keep_last: int = 2):
        steps = sorted([int(f[len("manifest_"):-5])
                        for f in os.listdir(self.root)
                        if f.startswith("manifest_")])
        keep = set(steps[-keep_last:])
        live = set()
        for s in keep:
            with open(os.path.join(self.root, f"manifest_{s:08d}.json")) as f:
                live |= {d["hash"] for d in json.load(f)["data"].values()}
        for s in steps:
            if s not in keep:
                os.remove(os.path.join(self.root, f"manifest_{s:08d}.json"))
        for c in os.listdir(os.path.join(self.root, "chunks")):
            if c[:-4] not in live:
                os.remove(os.path.join(self.root, "chunks", c))
