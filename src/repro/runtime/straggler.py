"""Straggler mitigation for the dispatch pipeline.

The deferral CommitQueue gives a natural interposition point: every commit
has a measurable latency.  ``DispatchMonitor`` keeps an EWMA + variance of
commit latencies per stream; a commit exceeding ``factor x EWMA`` flags the
stream as straggling, which triggers (a) re-dispatch of the speculative
segment on a backup stream (serving), or (b) work-stealing in the data
loader (training).  At 1000+ nodes the same monitor runs per-host and
feeds the coordinator via metastate-only sync.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Dict, Optional


class DispatchMonitor:
    def __init__(self, factor: float = 3.0, alpha: float = 0.2,
                 min_samples: int = 5):
        self.factor = factor
        self.alpha = alpha
        self.min_samples = min_samples
        self.ewma: Dict[str, float] = {}
        self.count: Dict[str, int] = collections.Counter()
        self.flagged: collections.Counter = collections.Counter()

    def observe(self, stream: str, latency_s: float) -> bool:
        """Record a commit latency; True if this commit straggles."""
        n = self.count[stream]
        self.count[stream] += 1
        if n == 0:
            self.ewma[stream] = latency_s
            return False
        mean = self.ewma[stream]
        straggle = (n >= self.min_samples and
                    latency_s > self.factor * max(mean, 1e-9))
        self.ewma[stream] = (1 - self.alpha) * mean + self.alpha * latency_s
        if straggle:
            self.flagged[stream] += 1
        return straggle

    def timed(self, stream: str, fn: Callable, *args,
              backup: Optional[Callable] = None):
        """Run fn; on straggle, re-dispatch on `backup` (first result wins —
        here sequential emulation: backup result replaces)."""
        t0 = time.time()
        out = fn(*args)
        if self.observe(stream, time.time() - t0) and backup is not None:
            out = backup(*args)
        return out
