"""Slot-based KV cache management for continuous batching.

The allocation table (slot -> request, lengths, positions) is pure
METASTATE (repro.core.metasync): it is what crosses hosts, what rollback
restores, and what checkpoints inline — KV pages themselves never travel
(paper §5).  Stale cache rows beyond a sequence's committed position are
harmless by construction (decode masks on ``pos``), which is what makes
metastate-only rollback sound.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class SlotTable:
    """Metastate: the engine's 'page table'."""
    n_slots: int

    def __post_init__(self):
        self.request_id = np.full(self.n_slots, -1, np.int64)
        self.pos = np.zeros(self.n_slots, np.int32)        # next write slot
        self.committed_pos = np.zeros(self.n_slots, np.int32)
        self.done = np.ones(self.n_slots, bool)            # free == done

    # -- metastate dict for metasync / checkpoints --
    def meta(self) -> Dict[str, np.ndarray]:
        return {"request_id": self.request_id.copy(),
                "pos": self.pos.copy(),
                "committed_pos": self.committed_pos.copy(),
                "done": self.done.copy()}

    def restore(self, meta: Dict[str, np.ndarray]):
        self.request_id = np.array(meta["request_id"])
        self.pos = np.array(meta["pos"])
        self.committed_pos = np.array(meta["committed_pos"])
        self.done = np.array(meta["done"])

    def free_slots(self) -> List[int]:
        return np.flatnonzero(self.done).tolist()

    def active_mask(self) -> np.ndarray:
        """Boolean mask of occupied slots (vectorized hot-path helper)."""
        return ~self.done

    def alloc(self, request_id: int, prompt_len: int) -> Optional[int]:
        free = np.flatnonzero(self.done)
        if free.size == 0:
            return None
        s = int(free[0])
        self.request_id[s] = request_id
        self.pos[s] = prompt_len
        self.committed_pos[s] = prompt_len
        self.done[s] = False
        return s

    def release(self, slot: int):
        self.request_id[slot] = -1
        self.done[slot] = True
        self.pos[slot] = 0
        self.committed_pos[slot] = 0
