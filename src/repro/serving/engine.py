"""Engine — thin single-stream facade over the layered serving stack.

The monolithic engine is gone; serving is now three layers (see
``repro.serving.scheduler``):

  * ``Scheduler``      — admission across streams, slot pressure,
                         preemption/eviction of stalled streams;
  * ``StreamExecutor`` — one tenant's CommitQueue + pipeline of in-flight
                         fused blocks over its ``ExecutionChannel``;
  * ``CommitFrontier`` — the ONLY host<->device sync point: metastate
                         readback, rollback-by-not-applying on mispredict.

``Engine`` keeps the original single-workload API — constructor, ``submit``
/ ``step_block`` / ``validate`` / ``run``, ``stats`` / ``requests`` /
``slots`` / ``spec`` — by wiring ONE stream through that stack, so every
pre-existing test, launcher, and benchmark runs unchanged while
multi-tenant callers use the ``Scheduler`` directly.  The execution
transport is an ``ExecutionChannel`` (live-jit, signed-replay, or
netem-billed — ``repro.core.channel``); raw ``prefill_fn`` /
``fused_decode_fn`` callables are wrapped into a ``LiveChannel`` for
backward compatibility.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax

from repro.core.channel import ExecutionChannel, LiveChannel
from repro.serving.executor import Request, StreamExecutor  # noqa: F401
from repro.serving.scheduler import Scheduler

__all__ = ["Engine", "Request", "StreamExecutor", "Scheduler",
           "cache_batch_axes_for"]


class Engine:
    """prefill_fn(params, batch) -> ({"next_tokens", ...}, caches_for_slot)
    fused_decode_fn(params, tokens, pos, caches) -> ({"tokens":[B,k],
    "pos", "done"}, caches).  Both may be live jits or Replayer handles —
    or pass ``channel=`` (any ``ExecutionChannel``) instead.

    ``batched_prefill_fn(params, tokens[B,S], lengths[B])`` (optional)
    enables grouped admission; ``pipeline_depth`` bounds how many decode
    blocks may be in flight before the frontier must drain.
    """

    def __init__(self, params, prefill_fn=None, fused_decode_fn=None, *,
                 n_slots: int, cache_len: int, block_k: int, eos_id: int = 2,
                 init_caches_fn=None, cache_batch_axes=None, netem=None,
                 spec_k: int = 3, speculate: bool = True,
                 pipeline_depth: int = 4, batched_prefill_fn=None,
                 prefill_buckets: Sequence[int] = (8, 16, 32, 64, 128),
                 channel: Optional[ExecutionChannel] = None,
                 stream_name: str = "stream0", tracer=None, metrics=None):
        if channel is None:
            if prefill_fn is None or fused_decode_fn is None:
                raise ValueError("Engine needs either channel= or both "
                                 "prefill_fn and fused_decode_fn")
            channel = LiveChannel(prefill_fn, fused_decode_fn,
                                  batched_prefill_fn)
        self.scheduler = Scheduler(netem=netem, spec_k=spec_k,
                                   tracer=tracer, metrics=metrics)
        self.stream = self.scheduler.add_stream(
            stream_name, channel, params, n_slots=n_slots,
            cache_len=cache_len, block_k=block_k, eos_id=eos_id,
            init_caches_fn=init_caches_fn,
            cache_batch_axes=cache_batch_axes, speculate=speculate,
            pipeline_depth=pipeline_depth, prefill_buckets=prefill_buckets)
        self.channel = channel
        self.frontier = self.scheduler.frontier
        self.fixed_prompt_len = channel.fixed_prompt_len
        self.registry_client = None

    # ------------------------------------------------- stream pass-through --
    @property
    def params(self):
        return self.stream.params

    @property
    def stats(self):
        return self.stream.stats

    @property
    def metrics(self):
        return self.scheduler.metrics

    @property
    def spec(self):
        return self.scheduler.spec

    @property
    def slots(self):
        return self.stream.slots

    @property
    def caches(self):
        return self.stream.caches

    @property
    def requests(self):
        return self.stream.requests

    @property
    def pending(self):
        return self.stream.pending

    @property
    def queue(self):
        return self.stream.queue

    @property
    def inflight(self):
        return self.stream.inflight

    @property
    def pipeline_depth(self):
        return self.stream.pipeline_depth

    @property
    def speculate(self):
        return self.stream.speculate

    # ------------------------------------------------------------- public --
    def submit(self, prompt: List[int], max_new: int) -> int:
        return self.stream.submit(prompt, max_new)

    def step_block(self) -> int:
        return self.stream.step_block()

    def validate(self) -> bool:
        """Drain the commit frontier for this engine's stream."""
        return self.frontier.drain(self.stream)

    def run(self, max_blocks: int = 10_000,
            validate_every: Optional[int] = None):
        """Serve until drained.  The frontier is visited every
        ``validate_every`` blocks (default: the pipeline depth)."""
        validate_every = validate_every or self.stream.pipeline_depth
        b = 0
        while self.stream.has_work() and b < max_blocks:
            self.step_block()
            b += 1
            if b % validate_every == 0:
                self.validate()
        self.validate()
        return self.stream.outputs()


def cache_batch_axes_for(cfg) -> List[int]:
    """Per-leaf batch-axis positions, derived from the model's cache axes
    metadata (leaves align with jax.tree.leaves of the cache pytree)."""
    from repro.models import model as M
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    flat = jax.tree.flatten(M.cache_axes(cfg), is_leaf=is_ax)[0]
    return [ax.index("batch") for ax in flat]
