"""Serving engine: continuous batching + the paper's three optimizations.

Decode runs in fused k-step blocks (ONE host dispatch per k tokens — the
paper's register-access deferral + §4.3 polling-loop offload: the EOS
"poll" lives device-side inside the block).  The host pipeline goes further
with *speculative continuation* (§4.2): it dispatches the next block
WITHOUT waiting for the previous block's done-mask readback when the
commit history is k-confident that nothing finished; validation happens at
the commit frontier, and a mispredict rolls back pure metastate (positions,
token tails) — the paper's replay-based recovery, cheap because KV rows
beyond the committed position are inert.

The engine can execute through live jitted functions OR through signed
recordings via the Replayer (``use_replayer=True``) — the latter is the
paper's in-TEE mode and imports no model code at decode time.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deferral import CommitQueue, Op
from repro.core.speculation import HistorySpeculator
from repro.serving.cache import SlotTable


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    committed: int = 0            # validated prefix of `generated`
    done: bool = False
    submit_t: float = 0.0
    finish_t: float = 0.0


class Engine:
    """prefill_fn(params, batch) -> ({"next_tokens", ...}, caches_for_slot)
    fused_decode_fn(params, tokens, pos, caches) -> ({"tokens":[B,k],
    "pos", "done"}, caches).  Both may be live jits or Replayer handles.
    """

    def __init__(self, params, prefill_fn, fused_decode_fn, *, n_slots: int,
                 cache_len: int, block_k: int, eos_id: int = 2,
                 init_caches_fn=None, cache_batch_axes=None, netem=None,
                 spec_k: int = 3, speculate: bool = True):
        self.params = params
        self.prefill_fn = prefill_fn
        self.fused_decode_fn = fused_decode_fn
        self.block_k = block_k
        self.eos_id = eos_id
        self.netem = netem
        self.slots = SlotTable(n_slots)
        self.caches = init_caches_fn() if init_caches_fn else None
        # per-leaf position of the batch axis (leading dims may be stage
        # stacks); provided by the launcher from model.cache_axes
        self._batch_axes = cache_batch_axes
        self.requests: Dict[int, Request] = {}
        self.pending: collections.deque = collections.deque()
        self.queue = CommitQueue(self._channel, netem=netem, name="decode")
        self.spec = HistorySpeculator(k=spec_k)
        self.speculate = speculate
        self.inflight: List[dict] = []     # speculative (unvalidated) blocks
        self.stats = collections.Counter()
        self._slot_tokens = np.zeros(n_slots, np.int32)

    # ------------------------------------------------------------ channel --
    def _channel(self, op: Op):
        """Device-side execution of one interaction (the 'client GPU')."""
        if op.kind == "write":      # dispatch a fused decode block
            self._dispatch_block()
            return None
        if op.kind == "read":       # read back done mask + new tokens
            return self._last_block_result
        return None

    def _dispatch_block(self):
        toks = jnp.asarray(self._slot_tokens)
        pos = jnp.asarray(self.slots.pos)
        out, self.caches = self.fused_decode_fn(
            self.params, toks, pos, self.caches)
        tokens = np.asarray(out["tokens"])          # [B, k]
        done = np.asarray(out["done"])
        newpos = np.asarray(out["pos"])
        self._last_block_result = (tokens.tobytes(), done.tobytes(),
                                   newpos.tobytes())
        self._last_block_arrays = (tokens, done, newpos)
        self.stats["blocks_dispatched"] += 1

    # ------------------------------------------------------------- public --
    def submit(self, prompt: List[int], max_new: int) -> int:
        rid = len(self.requests)
        self.requests[rid] = Request(rid, list(prompt), max_new,
                                     submit_t=time.time())
        self.pending.append(rid)
        return rid

    def _admit(self):
        while self.pending and self.slots.free_slots():
            rid = self.pending[0]
            req = self.requests[rid]
            slot = self.slots.alloc(rid, len(req.prompt))
            if slot is None:
                return
            self.pending.popleft()
            self._prefill_into_slot(req, slot)
            self.stats["admitted"] += 1

    def _prefill_into_slot(self, req: Request, slot: int):
        batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
        out, caches = self.prefill_fn(self.params, batch)
        first = int(np.asarray(out["next_tokens"])[0])
        self._slot_tokens[slot] = first
        req.generated.append(first)
        # copy the single-sequence caches into this slot's row
        flat_c, td = jax.tree.flatten(self.caches)
        flat_n = jax.tree.leaves(caches)
        axes = self._batch_axes or [0] * len(flat_c)
        out_leaves = []
        for c, n, ax in zip(flat_c, flat_n, axes):
            row = jnp.take(n, 0, axis=ax)   # shapes align: same cache_len
            out_leaves.append(
                c.at[(slice(None),) * ax + (slot,)].set(row.astype(c.dtype)))
        self.caches = jax.tree.unflatten(td, out_leaves)
        if self.netem is not None:
            self.netem.round_trip()     # prefill is a synchronous commit

    # The decode pipeline: write(dispatch) + read(done mask) per block.
    def step_block(self):
        """One fused block for all active slots; returns #active."""
        self._admit()
        active = [i for i in range(self.slots.n_slots)
                  if not self.slots.done[i]]
        if not active:
            return 0
        snapshot = {"slots": self.slots.meta(),
                    "gen": {r.rid: list(r.generated)
                            for r in self.requests.values()},
                    "tok": self._slot_tokens.copy()}
        self.queue.write("decode.block")
        sym = self.queue.read("decode.done_mask")
        ops = list(self.queue.queue)
        pred = self.spec.predict(ops) if self.speculate else None
        if pred is not None:
            # speculative continuation: don't block on the readback
            self.queue.queue = []
            self.queue.execute_ops(ops)     # device runs; actual kept aside
            actual = self._last_block_arrays
            if self.netem is not None:
                self.netem.async_trip()
            self.inflight.append({"snapshot": snapshot, "ops": ops,
                                  "actual": actual, "pred": pred})
            self._apply_block(actual, speculative=True)
            self.stats["spec_blocks"] += 1
        else:
            self.queue.commit()
            actual = self._last_block_arrays
            self._apply_block(actual, speculative=False)
            outcome = ("all_running",) if not bool(actual[1].any()) \
                else ("some_done",)
            self.spec.record(ops, outcome)
            self._retire(actual)
            self.stats["sync_blocks"] += 1
        return len(active)

    def validate(self):
        """Commit frontier: validate speculative blocks in order (§4.2)."""
        while self.inflight:
            blk = self.inflight.pop(0)
            actual = blk["actual"]
            outcome = ("all_running",) if not bool(actual[1].any()) \
                else ("some_done",)
            self.spec.record(blk["ops"], outcome)
            if blk["pred"] != outcome:
                # mispredict: some sequence finished inside a speculative
                # block -> roll back metastate to the snapshot, re-apply the
                # block with EOS honored (replay from the log), drop the
                # rest of the speculative pipeline.
                self.stats["mispredicts"] += 1
                self.slots.restore(blk["snapshot"]["slots"])
                for rid, gen in blk["snapshot"]["gen"].items():
                    self.requests[rid].generated = list(gen)
                self._slot_tokens = blk["snapshot"]["tok"].copy()
                self._apply_block(actual, speculative=False)
                self._retire(actual)
                self.inflight.clear()
                return False
            self._retire(actual)
            self.stats["validated_blocks"] += 1
        # frontier clean: commit generated tails
        for req in self.requests.values():
            req.committed = len(req.generated)
        self.slots.committed_pos[:] = self.slots.pos
        return True

    # ------------------------------------------------------------ helpers --
    def _apply_block(self, actual, speculative: bool):
        tokens, done, newpos = actual
        for i in range(self.slots.n_slots):
            if self.slots.done[i]:
                continue
            rid = int(self.slots.request_id[i])
            req = self.requests[rid]
            new = [int(t) for t in tokens[i]]
            if not speculative and bool(done[i]):
                # truncate at EOS
                cut = next((j + 1 for j, t in enumerate(new)
                            if t == self.eos_id), len(new))
                new = new[:cut]
            req.generated.extend(new)
            self._slot_tokens[i] = new[-1] if new else self._slot_tokens[i]
        self.slots.pos[:] = np.asarray(newpos)[:self.slots.n_slots]

    def _retire(self, actual):
        _tokens, done, _ = actual
        for i in range(self.slots.n_slots):
            if self.slots.done[i]:
                continue
            rid = int(self.slots.request_id[i])
            req = self.requests[rid]
            over_budget = len(req.generated) >= req.max_new
            if bool(done[i]) or over_budget:
                if bool(done[i]):
                    cut = next((j + 1 for j, t in enumerate(req.generated)
                                if t == self.eos_id), len(req.generated))
                    req.generated = req.generated[:cut]
                req.generated = req.generated[:req.max_new]
                req.done = True
                req.finish_t = time.time()
                self.slots.release(i)
                self.stats["retired"] += 1

    def run(self, max_blocks: int = 10_000, validate_every: int = 4):
        b = 0
        while (self.pending or not all(self.slots.done)) and b < max_blocks:
            self.step_block()
            b += 1
            if b % validate_every == 0:
                self.validate()
        self.validate()
        return {rid: r.generated for rid, r in self.requests.items()}


def cache_batch_axes_for(cfg) -> List[int]:
    """Per-leaf batch-axis positions, derived from the model's cache axes
    metadata (leaves align with jax.tree.leaves of the cache pytree)."""
    from repro.models import model as M
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    flat = jax.tree.flatten(M.cache_axes(cfg), is_leaf=is_ax)[0]
    return [ax.index("batch") for ax in flat]
