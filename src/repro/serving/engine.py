"""Serving engine: continuous batching + the paper's three optimizations.

Decode runs in fused k-step blocks (ONE host dispatch per k tokens — the
paper's register-access deferral + §4.3 polling-loop offload: the EOS
"poll" lives device-side inside the block).  The hot path is a true
ASYNCHRONOUS PIPELINE: a dispatched block's outputs stay on device as
in-flight futures and the next block's inputs chain directly off them
(``tokens[:, -1]``, ``pos``), so up to ``pipeline_depth`` blocks are in
flight with ZERO host↔device syncs.  The only transfer is a small
done-mask/metastate readback at ``validate()`` — the commit frontier —
matching the paper's metastate-only sync (§5).

Speculative continuation (§4.2) decides whether chaining is allowed: when
the commit history is k-confident about the done-mask, blocks ship via
``CommitQueue.commit_async`` (no blocking round trip); otherwise the engine
falls back to a synchronous commit.  Because token tails are applied only
at the frontier, a mispredict (a sequence finished mid-pipeline) rolls
back by simply NOT applying the speculative tail — pure metastate, no
device work is redone; KV rows beyond the committed position are inert
(repro.serving.cache invariant).

Admission is batched: pending requests are grouped, right-padded to shape
buckets, prefilled in one dispatch, and scattered into the slot caches
with one vectorized indexed-set per cache leaf.  Right padding is sound
for attention families because decode masks cache rows >= pos; recurrent
families (ssm/hybrid/xlstm) must keep the per-request path (their state is
not position-indexed) — the launcher gates this.  The same non-position-
indexed argument means recurrent families should serve with
``speculate=False``: rolled-back pipeline tails cannot be re-executed
against an already-advanced state.

The engine can execute through live jitted functions OR through signed
recordings via the Replayer (``use_replayer=True``) — the latter is the
paper's in-TEE mode and imports no model code at decode time.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deferral import CommitQueue, Op
from repro.core.speculation import HistorySpeculator
from repro.serving.cache import SlotTable

ALL_RUNNING = ("all_running",)
SOME_DONE = ("some_done",)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    committed: int = 0            # validated prefix of `generated`
    done: bool = False
    submit_t: float = 0.0
    finish_t: float = 0.0


class Engine:
    """prefill_fn(params, batch) -> ({"next_tokens", ...}, caches_for_slot)
    fused_decode_fn(params, tokens, pos, caches) -> ({"tokens":[B,k],
    "pos", "done"}, caches).  Both may be live jits or Replayer handles.

    ``batched_prefill_fn(params, tokens[B,S], lengths[B])`` (optional)
    enables grouped admission; ``pipeline_depth`` bounds how many decode
    blocks may be in flight before the frontier must drain.
    """

    def __init__(self, params, prefill_fn, fused_decode_fn, *, n_slots: int,
                 cache_len: int, block_k: int, eos_id: int = 2,
                 init_caches_fn=None, cache_batch_axes=None, netem=None,
                 spec_k: int = 3, speculate: bool = True,
                 pipeline_depth: int = 4, batched_prefill_fn=None,
                 prefill_buckets: Sequence[int] = (8, 16, 32, 64, 128)):
        self.params = params
        self.prefill_fn = prefill_fn
        self.batched_prefill_fn = batched_prefill_fn
        self.fused_decode_fn = fused_decode_fn
        self.block_k = block_k
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.netem = netem
        self.slots = SlotTable(n_slots)
        self.caches = init_caches_fn() if init_caches_fn else None
        # per-leaf position of the batch axis (leading dims may be stage
        # stacks); provided by the launcher from model.cache_axes
        self._batch_axes = cache_batch_axes
        self.requests: Dict[int, Request] = {}
        self.pending: collections.deque = collections.deque()
        self.queue = CommitQueue(self._channel, netem=netem, name="decode")
        self.spec = HistorySpeculator(k=spec_k)
        self.speculate = speculate
        self.pipeline_depth = max(1, pipeline_depth)
        self.prefill_buckets = tuple(sorted(prefill_buckets))
        self.inflight: List[dict] = []     # unvalidated blocks (device futures)
        self.stats = collections.Counter()
        self._slot_tokens = np.zeros(n_slots, np.int32)
        # device-chained decode inputs; None => host metastate authoritative
        self._dev_tokens = None
        self._dev_pos = None
        self._last_block_out = None

    # ------------------------------------------------------------ channel --
    def _channel(self, op: Op):
        """Device-side execution of one interaction (the 'client GPU')."""
        if op.kind == "write":      # dispatch a fused decode block
            self._dispatch_block()
            return None
        if op.kind == "read":       # done mask + tokens: an in-flight future
            return self._last_block_out
        return None

    def _dispatch_block(self):
        if self._dev_tokens is None:   # re-seed the chain from host metastate
            self._dev_tokens = jnp.asarray(self._slot_tokens)
            self._dev_pos = jnp.asarray(self.slots.pos)
        out, self.caches = self.fused_decode_fn(
            self.params, self._dev_tokens, self._dev_pos, self.caches)
        # chain the NEXT block's inputs off this block's device outputs:
        # nothing is read back (the fused kernel freezes finished rows, so
        # tokens[:, -1]/pos are exactly what a host round trip would feed)
        self._dev_tokens = out["tokens"][:, -1]
        self._dev_pos = out["pos"]
        self._last_block_out = out
        self.stats["blocks_dispatched"] += 1

    def _materialize(self, out):
        """Host←device transfer of one block's metastate (tokens/done/pos).
        Call sites account ``stats['host_syncs']`` — a frontier drain is ONE
        stall no matter how many blocks it validates."""
        return (np.asarray(out["tokens"]), np.asarray(out["done"]),
                np.asarray(out["pos"]))

    # ------------------------------------------------------------- public --
    def submit(self, prompt: List[int], max_new: int) -> int:
        rid = len(self.requests)
        self.requests[rid] = Request(rid, list(prompt), max_new,
                                     submit_t=time.time())
        self.pending.append(rid)
        return rid

    # ---------------------------------------------------------- admission --
    def _admit(self):
        if not self.pending or not self.slots.done.any():
            return
        if self.inflight:
            # admission changes the decode batch and re-seeds the device
            # chain from host metastate — which is STALE while blocks are
            # in flight (tails apply at the frontier).  Drain first.
            self.validate()
        group = []
        while self.pending:
            rid = self.pending[0]
            req = self.requests[rid]
            slot = self.slots.alloc(rid, len(req.prompt))
            if slot is None:
                break
            self.pending.popleft()
            group.append((req, slot))
        if not group:
            return
        self._dev_tokens = None            # host metastate changes below
        if self.batched_prefill_fn is None:
            for req, slot in group:
                self._prefill_into_slot(req, slot)
        else:
            for plen, members in sorted(self._bucketize(group).items()):
                self._prefill_group(members, plen)
        self.stats["admitted"] += len(group)

    def _bucketize(self, group):
        """Group (request, slot) pairs by padded prompt length so each
        bucket is ONE prefill dispatch (and one jit shape)."""
        buckets: Dict[int, list] = {}
        for req, slot in group:
            plen = len(req.prompt)
            padded = next((b for b in self.prefill_buckets if b >= plen),
                          plen)
            padded = max(min(padded, self.cache_len), plen)
            buckets.setdefault(padded, []).append((req, slot))
        return buckets

    def _prefill_group(self, members, padded_len: int):
        """One dispatch for a whole bucket.  Right padding is sound: each
        row's next token is read at its true last position and decode masks
        cache rows >= pos, so pad garbage in the caches is inert."""
        toks = np.zeros((len(members), padded_len), np.int32)
        lens = np.empty(len(members), np.int32)
        for row, (req, _slot) in enumerate(members):
            toks[row, :len(req.prompt)] = req.prompt
            lens[row] = len(req.prompt)
        out, caches = self.batched_prefill_fn(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        firsts = np.asarray(out["next_tokens"])
        for row, (req, slot) in enumerate(members):
            self._slot_tokens[slot] = int(firsts[row])
            req.generated.append(int(firsts[row]))
        self._scatter_caches(caches, np.array([s for _, s in members]))
        if self.netem is not None:
            self.netem.round_trip()    # ONE synchronous commit per bucket
        self.stats["prefill_dispatches"] += 1

    def _scatter_caches(self, new_caches, slots_arr: np.ndarray):
        """Vectorized scatter of a prefilled group into the slot caches:
        one indexed ``.set`` per cache leaf (not per request per leaf)."""
        flat_c, td = jax.tree.flatten(self.caches)
        flat_n = jax.tree.leaves(new_caches)
        axes = self._batch_axes or [0] * len(flat_c)
        idx = jnp.asarray(slots_arr)
        out_leaves = []
        for c, n, ax in zip(flat_c, flat_n, axes):
            sel = (slice(None),) * ax + (idx,)
            out_leaves.append(c.at[sel].set(n.astype(c.dtype)))
        self.caches = jax.tree.unflatten(td, out_leaves)

    def _prefill_into_slot(self, req: Request, slot: int):
        """Per-request path: exact shapes (required for recorded prefill
        executables and for recurrent-state families)."""
        batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
        out, caches = self.prefill_fn(self.params, batch)
        first = int(np.asarray(out["next_tokens"])[0])
        self._slot_tokens[slot] = first
        req.generated.append(first)
        self._scatter_caches(caches, np.array([slot]))
        if self.netem is not None:
            self.netem.round_trip()     # prefill is a synchronous commit
        self.stats["prefill_dispatches"] += 1

    # ------------------------------------------------------------- decode --
    def step_block(self):
        """One fused block for all active slots; returns #active.

        With speculation, up to ``pipeline_depth`` blocks stay in flight as
        device futures (shipped via ``commit_async``); without it — or when
        history is not k-confident — the block commits synchronously."""
        if len(self.inflight) >= self.pipeline_depth:
            self.validate()            # frontier full: drain before refill
        self._admit()
        active = int(self.slots.active_mask().sum())
        if not active:
            return 0
        self.queue.write("decode.block")
        self.queue.read("decode.done_mask")
        ops = list(self.queue.queue)
        pred = self.spec.predict(ops) if self.speculate else None
        if pred is not None:
            # speculative continuation: ship without blocking; token tails
            # are applied (and validated) only at the commit frontier
            self.queue.commit_async()
            self.inflight.append({"ops": ops, "out": self._last_block_out,
                                  "pred": pred})
            self.stats["spec_blocks"] += 1
        else:
            if self.inflight:
                self.validate()        # program order: drain, then block
            self.queue.commit()
            actual = self._materialize(self._last_block_out)
            self.stats["host_syncs"] += 1
            self._apply_block(actual, speculative=False)
            self.spec.record(
                ops, SOME_DONE if actual[1].any() else ALL_RUNNING)
            self._retire(actual)
            self.stats["sync_blocks"] += 1
        return active

    def validate(self):
        """Commit frontier (§4.2 + §5): ONE metastate readback validates
        every in-flight block in order.  A mispredict — some sequence
        finished inside the pipeline — applies the offending block with EOS
        honored and simply DROPS the speculative tail: metastate-only
        rollback, no device work is redone."""
        ok = True
        if self.inflight:
            pipeline, self.inflight = self.inflight, []
            self.stats["host_syncs"] += 1      # one stall for the drain
            if self.netem is not None:
                # the paper's metastate-only sync: done masks + token tails
                n, k = self.slots.n_slots, self.block_k
                self.netem.round_trip(
                    send_bytes=64,
                    recv_bytes=len(pipeline) * n * (4 * k + 5))
            for b_idx, blk in enumerate(pipeline):
                actual = self._materialize(blk["out"])
                outcome = SOME_DONE if actual[1].any() else ALL_RUNNING
                self.spec.record(blk["ops"], outcome)
                if blk["pred"] != outcome:
                    self.stats["mispredicts"] += 1
                    self._apply_block(actual, speculative=False)
                    self._retire(actual)
                    self._dev_tokens = None    # chain built on a lie
                    self.stats["dropped_blocks"] += len(pipeline) - b_idx - 1
                    ok = False
                    break
                self._apply_block(
                    actual, speculative=outcome == ALL_RUNNING)
                self._retire(actual)
                self.stats["validated_blocks"] += 1
        # frontier clean: commit generated tails
        for req in self.requests.values():
            req.committed = len(req.generated)
        self.slots.committed_pos[:] = self.slots.pos
        return ok

    # ------------------------------------------------------------ helpers --
    def _apply_block(self, actual, speculative: bool):
        """Extend per-request tails from one block's metastate.  Mask math
        is vectorized; only the list extends touch Python objects."""
        tokens, done, newpos = actual
        n = self.slots.n_slots
        live = self.slots.active_mask()
        if not live.any():
            return
        k = tokens.shape[1]
        cut = np.full(n, k, np.int64)
        if not speculative:
            iseos = tokens[:n] == self.eos_id
            hit = iseos.any(axis=1) & np.asarray(done[:n], bool)
            if hit.any():
                cut[hit] = iseos[hit].argmax(axis=1) + 1
        last = tokens[np.arange(n), cut - 1]
        for i in np.flatnonzero(live):
            req = self.requests[int(self.slots.request_id[i])]
            req.generated.extend(int(t) for t in tokens[i, :cut[i]])
        self._slot_tokens[live] = last[live]
        self.slots.pos[live] = np.asarray(newpos)[:n][live]

    def _retire(self, actual):
        _tokens, done, _ = actual
        done = np.asarray(done[: self.slots.n_slots], bool)
        for i in np.flatnonzero(self.slots.active_mask()):
            req = self.requests[int(self.slots.request_id[i])]
            if not (done[i] or len(req.generated) >= req.max_new):
                continue
            if done[i]:
                g = np.asarray(req.generated)
                eos = np.flatnonzero(g == self.eos_id)
                if eos.size:                   # truncate at first EOS
                    req.generated = req.generated[:int(eos[0]) + 1]
            req.generated = req.generated[:req.max_new]
            req.done = True
            req.finish_t = time.time()
            self.slots.release(i)
            self._dev_tokens = None            # slot table changed
            self.stats["retired"] += 1

    def run(self, max_blocks: int = 10_000,
            validate_every: Optional[int] = None):
        """Serve until drained.  The frontier is visited every
        ``validate_every`` blocks (default: the pipeline depth)."""
        validate_every = validate_every or self.pipeline_depth
        b = 0
        while (self.pending or not all(self.slots.done)) and b < max_blocks:
            self.step_block()
            b += 1
            if b % validate_every == 0:
                self.validate()
        self.validate()
        return {rid: r.generated for rid, r in self.requests.items()}


def cache_batch_axes_for(cfg) -> List[int]:
    """Per-leaf batch-axis positions, derived from the model's cache axes
    metadata (leaves align with jax.tree.leaves of the cache pytree)."""
    from repro.models import model as M
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    flat = jax.tree.flatten(M.cache_axes(cfg), is_leaf=is_ax)[0]
    return [ax.index("batch") for ax in flat]
