"""Int8 weight quantization for serving (beyond-paper optimization).

Decode is memory-bound: every token reads all weights.  Storing matmul
weights as int8 + per-output-channel fp32 scales halves the weight bytes
vs bf16 (T_memory term) and halves resident weight memory.  Dequantization
is fused into the consuming matmul on TPU (convert+mul fuse into the MXU
operand load), so HBM traffic is int8 — the HLO analyzer traces dot
operands back through elementwise chains to the int8 parameter to account
this (analysis/hlo.py source-tracing).

Norm scales, biases, gates and small tensors stay in their original dtype
(accuracy + they are noise in the byte budget).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

QUANT_MIN_SIZE = 1 << 14   # only quantize big matmul weights


def _is_quantizable(leaf) -> bool:
    return (hasattr(leaf, "dtype") and leaf.dtype == jnp.bfloat16
            and leaf.ndim >= 2 and leaf.size >= QUANT_MIN_SIZE)


def quantize_params(params):
    """pytree of weights -> pytree where big bf16 leaves become
    {"q": int8, "s": f32 per-output-channel scale} (last dim channels)."""
    def one(leaf):
        if not _is_quantizable(leaf):
            return leaf
        f = leaf.astype(jnp.float32)
        s = jnp.maximum(jnp.max(jnp.abs(f), axis=-1, keepdims=True),
                        1e-8) / 127.0
        q = jnp.clip(jnp.round(f / s), -127, 127).astype(jnp.int8)
        return {"q": q, "s": s}
    return jax.tree.map(one, params)


def abstract_quantized(params_abstract):
    def one(leaf):
        if not _is_quantizable(leaf):
            return leaf
        return {"q": jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                "s": jax.ShapeDtypeStruct(leaf.shape[:-1] + (1,),
                                          jnp.float32)}
    return jax.tree.map(one, params_abstract)


def quantized_axes(params_axes, params_abstract):
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    flat_ax = jax.tree.flatten(params_axes, is_leaf=is_ax)[0]
    flat_ab, _ = jax.tree.flatten(params_abstract)
    out = []
    for ax, ab in zip(flat_ax, flat_ab):
        if _is_quantizable(ab):
            out.append({"q": ax, "s": ax[:-1] + (None,)})
        else:
            out.append(ax)
    treedef = jax.tree.structure(params_abstract)
    return jax.tree.unflatten(treedef, out)


def dequantize(params_q, dtype=jnp.bfloat16):
    """Inverse transform (applied inside the jitted step; XLA fuses the
    convert into consumers tile-by-tile on TPU)."""
    def is_q(x):
        return isinstance(x, dict) and set(x) == {"q", "s"}

    def one(x):
        if is_q(x):
            return (x["q"].astype(jnp.float32) * x["s"]).astype(dtype)
        return x
    return jax.tree.map(one, params_q, is_leaf=is_q)
