"""CommitFrontier — the ONE host<->device synchronization point (§4.2+§5).

Every readback in the serving stack funnels through this object: a
frontier drain materializes the metastate (tokens / done mask / pos) of
every in-flight block of ONE stream in program order — one stall no
matter how many blocks it validates — and a synchronous fallback commit
is a one-block drain.  Nothing else in the stack calls ``np.asarray`` on
device values, which is what keeps the pipeline's "only transfer is the
frontier" invariant checkable (the benchmarks count ``host_syncs``).

Rollback is BY NOT APPLYING: a mispredicted block (a sequence finished
mid-pipeline) is applied with EOS honored and the speculative tail behind
it is dropped — pure metastate, no device work is redone (KV rows beyond
the committed position are inert, repro.serving.cache invariant).
"""
from __future__ import annotations

import collections

import numpy as np

from repro.obs.trace import NULL, traced

ALL_RUNNING = ("all_running",)
SOME_DONE = ("some_done",)


class CommitFrontier:
    """Validates in-flight blocks; owns all host-sync accounting."""

    def __init__(self):
        self.stats = collections.Counter()
        self.tracer = NULL      # set by the Scheduler when tracing is on

    # ---------------------------------------------------------- readback --
    @staticmethod
    def materialize(out):
        """Host←device transfer of one block's metastate.  Callers never
        count this directly — ``drain``/``read_now`` account the stall."""
        return (np.asarray(out["tokens"]), np.asarray(out["done"]),
                np.asarray(out["pos"]))

    def read_now(self, stream, out):
        """Synchronous-commit readback: ONE stall for one block (the
        non-speculative fallback path)."""
        stream.stats["host_syncs"] += 1
        self.stats["host_syncs"] += 1
        if self.tracer:
            self.tracer.instant("host_sync", f"serve.{stream.name}",
                                kind="read_now")
        return self.materialize(out)

    # ------------------------------------------------------------- drain --
    def drain(self, stream) -> bool:
        """Validate every in-flight block of ``stream`` in order with ONE
        metastate readback, then commit the generated tails.  Returns
        False when a mispredict dropped the tail of the pipeline."""
        ok = True
        if stream.inflight:
            pipeline, stream.inflight = stream.inflight, []
            stream.stats["host_syncs"] += 1    # one stall for the drain
            self.stats["host_syncs"] += 1
            self.stats["drains"] += 1
            track = f"serve.{stream.name}"
            if self.tracer:
                self.tracer.instant("host_sync", track, kind="drain",
                                    blocks=len(pipeline))
            with traced(self.tracer, "frontier.drain", track,
                        blocks=len(pipeline)):
                if stream.netem is not None:
                    # the paper's metastate-only sync: done masks + token
                    # tails
                    n, k = stream.slots.n_slots, stream.block_k
                    stream.netem.round_trip(
                        send_bytes=64,
                        recv_bytes=len(pipeline) * n * (4 * k + 5))
                for b_idx, blk in enumerate(pipeline):
                    actual = self.materialize(blk["out"])
                    outcome = SOME_DONE if actual[1].any() else ALL_RUNNING
                    stream.spec.record(blk["ops"], outcome,
                                       stream=stream.name)
                    if blk["pred"] != outcome:
                        stream.stats["mispredicts"] += 1
                        self.stats["mispredicts"] += 1
                        if self.tracer:
                            self.tracer.instant(
                                "frontier.mispredict", track,
                                dropped=len(pipeline) - b_idx - 1)
                        stream.apply_block(actual, speculative=False)
                        stream.retire(actual)
                        stream.reset_device_chain()  # chain built on a lie
                        dropped = len(pipeline) - b_idx - 1
                        stream.stats["dropped_blocks"] += dropped
                        ok = False
                        break
                    stream.apply_block(
                        actual, speculative=outcome == ALL_RUNNING)
                    stream.retire(actual)
                    stream.stats["validated_blocks"] += 1
                    self.stats["validated_blocks"] += 1
        # frontier clean: commit generated tails
        for req in stream.requests.values():
            req.committed = len(req.generated)
        stream.slots.committed_pos[:] = stream.slots.pos
        return ok

    def drain_all(self, streams) -> bool:
        ok = True
        for s in streams:
            ok = self.drain(s) and ok
        return ok
