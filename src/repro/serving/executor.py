"""StreamExecutor — one tenant's decode pipeline over one ExecutionChannel.

A stream owns everything whose corruption could leak across tenants: its
``SlotTable``, its KV caches, its ``CommitQueue`` (program order is a
per-stream property), and its pipeline of in-flight fused blocks.  What
it does NOT own is shared serving infrastructure: the
``HistorySpeculator`` (keyed by ``(stream, site)`` so histories never
mix) and the ``CommitFrontier`` (the single host<->device sync point)
are handed in by the scheduler.

The hot path is unchanged from the single-tenant engine: decode runs in
fused k-step blocks, a dispatched block's outputs stay on device and the
next block's inputs chain off them, up to ``pipeline_depth`` blocks in
flight with zero host syncs; speculation decides whether a block ships
via ``commit_async`` or falls back to a synchronous commit.  Token tails
apply only at the frontier, so rollback is by not applying.

Preemption support: ``preempt()`` drains the frontier, releases every
active slot, and requeues the unfinished requests at the front of the
pending queue.  Because decoding is deterministic, a resumed request
re-prefills ``prompt + generated[:-1]`` and continues bit-exactly where
it was evicted (the re-predicted next token IS ``generated[-1]``); KV
rows left behind are inert.  Recorded-prefill channels pin the prompt
shape, so preemption requires ``channel.fixed_prompt_len is None``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import ExecutionChannel
from repro.core.deferral import CommitQueue, Op
from repro.obs.trace import NULL, traced
from repro.serving.cache import SlotTable
from repro.serving.frontier import ALL_RUNNING, SOME_DONE, CommitFrontier


class PreemptionUnsupportedError(RuntimeError):
    """The stream's channel pins the prefill shape; an evicted request
    could not be resumed (``prompt + generated[:-1]`` has a new length)."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    committed: int = 0            # validated prefix of `generated`
    done: bool = False
    failed: bool = False          # dropped (e.g. prefix outgrew the cache)
    submit_t: float = 0.0
    finish_t: float = 0.0

    def prefix(self) -> List[int]:
        """The tokens a (re-)admission must prefill: the prompt, plus — for
        a request resumed after preemption — all but the last committed
        token (decode re-consumes ``generated[-1]`` as its next input)."""
        if self.generated:
            return self.prompt + self.generated[:-1]
        return self.prompt


class StreamExecutor:
    """One stream's admission + pipelined fused-block decode."""

    def __init__(self, name: str, channel: ExecutionChannel, params, *,
                 n_slots: int, cache_len: int, block_k: int,
                 frontier: CommitFrontier, speculator, eos_id: int = 2,
                 init_caches_fn=None, cache_batch_axes=None, netem=None,
                 speculate: bool = True, pipeline_depth: int = 4,
                 prefill_buckets: Sequence[int] = (8, 16, 32, 64, 128),
                 admission_gate=None, tracer=None, metrics=None):
        self.name = name
        self.channel = channel
        self.params = params
        self.tracer = tracer if tracer is not None else NULL
        self.metrics = metrics
        self.track = f"serve.{name}"
        self.block_k = block_k
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.netem = netem
        self.frontier = frontier
        self.slots = SlotTable(n_slots)
        self.caches = init_caches_fn() if init_caches_fn else None
        self._init_caches_fn = init_caches_fn
        # per-leaf position of the batch axis (leading dims may be stage
        # stacks); provided by the launcher from model.cache_axes
        self._batch_axes = cache_batch_axes
        self.requests: Dict[int, Request] = {}
        self.pending: collections.deque = collections.deque()
        self._rid = 0              # monotonic: rids survive request removal
        self.queue = CommitQueue(self._exec_op, netem=netem, name=name)
        self.spec = speculator
        self.speculate = speculate
        self.pipeline_depth = max(1, pipeline_depth)
        self.prefill_buckets = tuple(sorted(prefill_buckets))
        # scheduler slot-pressure hook: admission asks before taking a slot
        self._admission_gate = admission_gate
        self.inflight: List[dict] = []     # unvalidated blocks (device futures)
        self.stats = collections.Counter()
        self._slot_tokens = np.zeros(n_slots, np.int32)
        # device-chained decode inputs; None => host metastate authoritative
        self._dev_tokens = None
        self._dev_pos = None
        self._last_block_out = None

    # ------------------------------------------------------------ channel --
    def _exec_op(self, op: Op):
        """CommitQueue channel: device-side execution of one interaction."""
        if op.kind == "write":      # dispatch a fused decode block
            self._dispatch_block()
            return None
        if op.kind == "read":       # done mask + tokens: an in-flight future
            return self._last_block_out
        return None

    def _dispatch_block(self):
        if self._dev_tokens is None:   # re-seed the chain from host metastate
            self._dev_tokens = jnp.asarray(self._slot_tokens)
            self._dev_pos = jnp.asarray(self.slots.pos)
        out, self.caches = self.channel.decode_block(
            self.params, self._dev_tokens, self._dev_pos, self.caches)
        # chain the NEXT block's inputs off this block's device outputs:
        # nothing is read back (the fused kernel freezes finished rows, so
        # tokens[:, -1]/pos are exactly what a host round trip would feed)
        self._dev_tokens = out["tokens"][:, -1]
        self._dev_pos = out["pos"]
        self._last_block_out = out
        self.stats["blocks_dispatched"] += 1

    def reset_device_chain(self):
        """Host metastate becomes authoritative: the next dispatch re-seeds
        its inputs instead of chaining off stale device futures."""
        self._dev_tokens = None
        self._dev_pos = None

    # ------------------------------------------------------------- public --
    def submit(self, prompt: List[int], max_new: int) -> int:
        rid = self._rid
        self._rid += 1
        self.requests[rid] = Request(rid, list(prompt), max_new,
                                     submit_t=time.time())
        self.pending.append(rid)
        return rid

    def adopt(self, req: Request) -> int:
        """Take over a request released by another executor (migration).
        The request keeps its generated tail; admission re-prefills
        ``prefix()`` and deterministic decode resumes it bit-exactly, the
        same mechanism preemption already relies on.  Returns the rid it
        was assigned HERE (rids are executor-local)."""
        rid = self._rid
        self._rid += 1
        req.rid = rid
        self.requests[rid] = req
        self.pending.append(rid)
        return rid

    def release_pending(self) -> List[Request]:
        """Remove and return every queued (non-active) request, in queue
        order, for adoption by another executor.  Callers preempt first so
        active requests land back in ``pending`` and are included."""
        released = []
        while self.pending:
            rid = self.pending.popleft()
            released.append(self.requests.pop(rid))
        if released:
            self.stats["released_requests"] += len(released)
        return released

    def has_work(self) -> bool:
        return bool(self.pending) or not all(self.slots.done)

    def committed_tokens(self) -> int:
        return sum(r.committed for r in self.requests.values())

    def progress_marker(self) -> tuple:
        """Device-progress fingerprint for the scheduler's stall detector:
        the active slot set and its positions.  A channel that stops
        advancing ``pos`` (a hung/frozen device) yields an identical
        marker across frontier drains even though speculative token tails
        may still be growing host-side."""
        live = self.slots.active_mask()
        return (tuple(self.slots.request_id[live].tolist()),
                tuple(self.slots.pos[live].tolist()))

    # ---------------------------------------------------------- admission --
    def _admit(self):
        if not self.pending or not self.slots.done.any():
            return
        budget = None          # scheduler slot pressure: None = unlimited
        if self._admission_gate is not None:
            budget = self._admission_gate(self)
            if budget <= 0:
                self.stats["admissions_deferred"] += 1
                return
        if self.inflight:
            # admission changes the decode batch and re-seeds the device
            # chain from host metastate — which is STALE while blocks are
            # in flight (tails apply at the frontier).  Drain first.
            self.frontier.drain(self)
        group = []
        while self.pending and (budget is None or len(group) < budget):
            rid = self.pending[0]
            req = self.requests[rid]
            if len(req.prefix()) + 1 > self.cache_len:
                # the prefix no longer fits the cache (a resumed request
                # that outgrew capacity): drop it rather than crash decode
                self.pending.popleft()
                req.done = True
                req.failed = True
                req.finish_t = time.time()
                self.stats["capacity_dropped"] += 1
                continue
            slot = self.slots.alloc(rid, len(req.prefix()))
            if slot is None:
                break
            self.pending.popleft()
            group.append((req, slot))
        if not group:
            return
        self.reset_device_chain()          # host metastate changes below
        if not self.channel.supports_batched_prefill:
            for req, slot in group:
                self._prefill_into_slot(req, slot)
        else:
            for plen, members in sorted(self._bucketize(group).items()):
                self._prefill_group(members, plen)
        self.stats["admitted"] += len(group)

    def _bucketize(self, group):
        """Group (request, slot) pairs by padded prompt length so each
        bucket is ONE prefill dispatch (and one jit shape)."""
        buckets: Dict[int, list] = {}
        for req, slot in group:
            plen = len(req.prefix())
            padded = next((b for b in self.prefill_buckets if b >= plen),
                          plen)
            padded = max(min(padded, self.cache_len), plen)
            buckets.setdefault(padded, []).append((req, slot))
        return buckets

    def _seed_slot(self, req: Request, slot: int, predicted_first: int):
        """Install a freshly prefilled request's next decode input.  For a
        resumed request the model re-predicts ``generated[-1]`` (greedy
        decode is deterministic), so the committed tail stays authoritative
        and nothing is appended twice."""
        if req.generated:
            self._slot_tokens[slot] = req.generated[-1]
        else:
            self._slot_tokens[slot] = predicted_first
            req.generated.append(predicted_first)

    def _prefill_group(self, members, padded_len: int):
        """One dispatch for a whole bucket.  Right padding is sound: each
        row's next token is read at its true last position and decode masks
        cache rows >= pos, so pad garbage in the caches is inert."""
        toks = np.zeros((len(members), padded_len), np.int32)
        lens = np.empty(len(members), np.int32)
        for row, (req, _slot) in enumerate(members):
            prefix = req.prefix()
            toks[row, :len(prefix)] = prefix
            lens[row] = len(prefix)
        with traced(self.tracer, "prefill.dispatch", self.track,
                    padded_len=padded_len, requests=len(members)):
            out, caches = self.channel.batched_prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens))
            firsts = np.asarray(out["next_tokens"])
            for row, (req, slot) in enumerate(members):
                self._seed_slot(req, slot, int(firsts[row]))
            self._scatter_caches(caches, np.array([s for _, s in members]))
            if self.netem is not None:
                self.netem.round_trip()  # ONE synchronous commit per bucket
        self.stats["prefill_dispatches"] += 1

    def _scatter_caches(self, new_caches, slots_arr: np.ndarray):
        """Vectorized scatter of a prefilled group into the slot caches:
        one indexed ``.set`` per cache leaf (not per request per leaf)."""
        flat_c, td = jax.tree.flatten(self.caches)
        flat_n = jax.tree.leaves(new_caches)
        axes = self._batch_axes or [0] * len(flat_c)
        idx = jnp.asarray(slots_arr)
        out_leaves = []
        for c, n, ax in zip(flat_c, flat_n, axes):
            sel = (slice(None),) * ax + (idx,)
            out_leaves.append(c.at[sel].set(n.astype(c.dtype)))
        self.caches = jax.tree.unflatten(td, out_leaves)

    def _prefill_into_slot(self, req: Request, slot: int):
        """Per-request path: exact shapes (required for recorded prefill
        executables and for recurrent-state families)."""
        with traced(self.tracer, "prefill.dispatch", self.track,
                    rid=req.rid, prefix_len=len(req.prefix())):
            batch = {"tokens": jnp.asarray([req.prefix()], jnp.int32)}
            out, caches = self.channel.prefill(self.params, batch)
            self._seed_slot(req, slot,
                            int(np.asarray(out["next_tokens"])[0]))
            self._scatter_caches(caches, np.array([slot]))
            if self.netem is not None:
                self.netem.round_trip()  # prefill is a synchronous commit
        self.stats["prefill_dispatches"] += 1

    # ------------------------------------------------------------- decode --
    def step_block(self):
        """One fused block for all active slots; returns #active.

        With speculation, up to ``pipeline_depth`` blocks stay in flight as
        device futures (shipped via ``commit_async``); without it — or when
        history is not k-confident — the block commits synchronously."""
        if len(self.inflight) >= self.pipeline_depth:
            self.frontier.drain(self)  # frontier full: drain before refill
        self._admit()
        active = int(self.slots.active_mask().sum())
        if not active:
            return 0
        self.queue.write("decode.block")
        self.queue.read("decode.done_mask")
        ops = list(self.queue.queue)
        pred = self.spec.predict(ops, stream=self.name) \
            if self.speculate else None
        if pred is not None:
            # speculative continuation: ship without blocking; token tails
            # are applied (and validated) only at the commit frontier
            with traced(self.tracer, "decode.block", self.track,
                        mode="spec", active=active):
                self.queue.commit_async()
            self.inflight.append({"ops": ops, "out": self._last_block_out,
                                  "pred": pred})
            self.stats["spec_blocks"] += 1
        else:
            if self.inflight:
                self.frontier.drain(self)  # program order: drain, then block
            with traced(self.tracer, "decode.block", self.track,
                        mode="sync", active=active):
                self.queue.commit()
                actual = self.frontier.read_now(self, self._last_block_out)
            self.apply_block(actual, speculative=False)
            self.spec.record(
                ops, SOME_DONE if actual[1].any() else ALL_RUNNING,
                stream=self.name)
            self.retire(actual)
            self.stats["sync_blocks"] += 1
        return active

    # --------------------------------------------------------- preemption --
    def preempt(self) -> List[int]:
        """Evict every active request: drain the frontier (their committed
        tails survive), free the slots, and requeue the unfinished requests
        at the FRONT of the pending queue in slot order.  Returns the
        requeued request ids."""
        if self.channel.fixed_prompt_len is not None:
            raise PreemptionUnsupportedError(
                f"stream '{self.name}': channel '{self.channel.kind}' pins "
                f"the prefill shape to {self.channel.fixed_prompt_len}; "
                "resumed prefixes would not match")
        self.frontier.drain(self)
        evicted = []
        for i in np.flatnonzero(self.slots.active_mask()):
            evicted.append(int(self.slots.request_id[i]))
            self.slots.release(int(i))
        for rid in reversed(evicted):
            self.pending.appendleft(rid)
        if evicted:
            self.reset_device_chain()      # slot table changed
            self.stats["preemptions"] += 1
            self.stats["evicted_requests"] += len(evicted)
        return evicted

    # ------------------------------------------------------------ helpers --
    def apply_block(self, actual, speculative: bool):
        """Extend per-request tails from one block's metastate.  Mask math
        is vectorized; only the list extends touch Python objects."""
        tokens, done, newpos = actual
        n = self.slots.n_slots
        live = self.slots.active_mask()
        if not live.any():
            return
        k = tokens.shape[1]
        cut = np.full(n, k, np.int64)
        if not speculative:
            iseos = tokens[:n] == self.eos_id
            hit = iseos.any(axis=1) & np.asarray(done[:n], bool)
            if hit.any():
                cut[hit] = iseos[hit].argmax(axis=1) + 1
        last = tokens[np.arange(n), cut - 1]
        for i in np.flatnonzero(live):
            req = self.requests[int(self.slots.request_id[i])]
            req.generated.extend(int(t) for t in tokens[i, :cut[i]])
        self._slot_tokens[live] = last[live]
        self.slots.pos[live] = np.asarray(newpos)[:n][live]

    def retire(self, actual):
        _tokens, done, _ = actual
        done = np.asarray(done[: self.slots.n_slots], bool)
        for i in np.flatnonzero(self.slots.active_mask()):
            req = self.requests[int(self.slots.request_id[i])]
            if not (done[i] or len(req.generated) >= req.max_new):
                continue
            if done[i]:
                g = np.asarray(req.generated)
                eos = np.flatnonzero(g == self.eos_id)
                if eos.size:                   # truncate at first EOS
                    req.generated = req.generated[:int(eos[0]) + 1]
            req.generated = req.generated[:req.max_new]
            req.done = True
            req.finish_t = time.time()
            self.slots.release(i)
            self.reset_device_chain()          # slot table changed
            self.stats["retired"] += 1
            if self.metrics is not None:
                self.metrics.histogram(
                    "request_latency_s", stream=self.name).observe(
                        req.finish_t - req.submit_t)
                self.metrics.counter(
                    "requests_retired", stream=self.name).inc()
                self.metrics.counter(
                    "tokens_generated", stream=self.name).inc(
                        len(req.generated))
            if self.tracer:
                self.tracer.instant("request.done", self.track, rid=req.rid,
                                    tokens=len(req.generated))

    def outputs(self) -> Dict[int, List[int]]:
        return {rid: r.generated for rid, r in self.requests.items()}
