"""Scheduler — multi-tenant serving over per-stream executors.

One scheduler serves N streams (model families / workloads) round-robin,
each through its own ``StreamExecutor`` and ``ExecutionChannel``, all
sharing ONE ``CommitFrontier`` (the only host<->device sync point) and
ONE ``HistorySpeculator`` (keyed by ``(stream, site)`` so per-stream
prediction dynamics are identical to serving that stream alone — the
bit-exactness guarantee the multi-tenant tests pin down).

Scheduler responsibilities, per layer:
  * admission — per-stream; a global ``max_live_slots`` budget applies
    back-pressure across tenants (slot pressure): a stream whose
    admission would push the fleet over budget defers until slots free;
  * shape-bucketing — the per-stream prefill bucket ladders are policy
    owned here and handed to the executors;
  * preemption/eviction — a stream that holds slots but commits no new
    tokens for ``stall_limit`` consecutive frontier drains is preempted:
    its unfinished requests are requeued (committed tails survive;
    deterministic decode resumes them bit-exactly) and its slots return
    to the pool.  ``preempt(name)`` does the same on demand.

Per-stream isolation: an executor touches only its own slots, caches,
and commit queue; the shared speculator never mixes histories across
streams; a replay-channel stream reaches decode without importing model
code (the channel trust boundary).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence

from repro.core.channel import ExecutionChannel
from repro.core.speculation import HistorySpeculator
from repro.obs.metrics import Metrics
from repro.obs.trace import NULL
from repro.serving.executor import (PreemptionUnsupportedError,
                                    StreamExecutor)
from repro.serving.frontier import CommitFrontier


class UnknownStreamError(KeyError):
    pass


class Scheduler:
    def __init__(self, *, netem=None, spec_k: int = 3,
                 max_live_slots: Optional[int] = None,
                 stall_limit: Optional[int] = None,
                 tracer=None, metrics: Optional[Metrics] = None):
        self.netem = netem
        self.tracer = tracer if tracer is not None else NULL
        self.metrics = metrics if metrics is not None else Metrics()
        self.frontier = CommitFrontier()
        self.frontier.tracer = self.tracer
        self.spec = HistorySpeculator(k=spec_k)
        self.streams: Dict[str, StreamExecutor] = {}
        self.max_live_slots = max_live_slots
        self.stall_limit = stall_limit
        self.counters = collections.Counter()
        self._progress: Dict[str, tuple] = {}  # slot marker at last drain
        self._stalled: Dict[str, int] = {}     # consecutive no-progress drains
        self._stall_hwm: Dict[str, int] = {}   # worst stall streak per stream
        self._blocks_since_drain: Dict[str, int] = {}
        self._unevictable: set = set()         # auto-eviction failed once

    # ------------------------------------------------------------ streams --
    def add_stream(self, name: str, channel: ExecutionChannel, params, *,
                   n_slots: int, cache_len: int, block_k: int,
                   eos_id: int = 2, init_caches_fn=None,
                   cache_batch_axes=None, speculate: bool = True,
                   pipeline_depth: int = 4,
                   prefill_buckets: Sequence[int] = (8, 16, 32, 64, 128),
                   ) -> StreamExecutor:
        if name in self.streams:
            raise ValueError(f"stream '{name}' already registered")
        ex = StreamExecutor(
            name, channel, params, n_slots=n_slots, cache_len=cache_len,
            block_k=block_k, frontier=self.frontier, speculator=self.spec,
            eos_id=eos_id, init_caches_fn=init_caches_fn,
            cache_batch_axes=cache_batch_axes, netem=self.netem,
            speculate=speculate, pipeline_depth=pipeline_depth,
            prefill_buckets=prefill_buckets,
            admission_gate=self._may_admit,
            tracer=self.tracer, metrics=self.metrics)
        self.streams[name] = ex
        self._progress[name] = ex.progress_marker()
        self._stalled[name] = 0
        self._stall_hwm[name] = 0
        self._blocks_since_drain[name] = 0
        return ex

    def stream(self, name: str) -> StreamExecutor:
        try:
            return self.streams[name]
        except KeyError:
            raise UnknownStreamError(name) from None

    # ---------------------------------------------------------- admission --
    def live_slots(self) -> int:
        return sum(int(ex.slots.active_mask().sum())
                   for ex in self.streams.values())

    def _may_admit(self, ex: StreamExecutor) -> int:
        """Slot-pressure gate: how many slots the stream may take without
        pushing the fleet past the global budget (a large number when no
        budget is set).  Per-stream slot tables still bound each tenant."""
        if self.max_live_slots is None:
            return ex.slots.n_slots
        return max(0, self.max_live_slots - self.live_slots())

    def submit(self, name: str, prompt: List[int], max_new: int) -> int:
        return self.stream(name).submit(prompt, max_new)

    # ----------------------------------------------------------- stepping --
    def has_work(self) -> bool:
        return any(ex.has_work() for ex in self.streams.values())

    def step(self, validate_every: Optional[int] = None) -> int:
        """One round-robin pass: each stream with work dispatches one fused
        block; a stream visits the frontier every ``validate_every`` of ITS
        OWN blocks (default: its pipeline depth), exactly as it would when
        served alone.  Returns the number of blocks stepped."""
        stepped = 0
        for name, ex in self.streams.items():
            if not ex.has_work():
                continue
            ex.step_block()
            stepped += 1
            self._blocks_since_drain[name] += 1
            if self._blocks_since_drain[name] >= \
                    (validate_every or ex.pipeline_depth):
                self.frontier.drain(ex)
                self._blocks_since_drain[name] = 0
                self._note_progress(name, ex)
        return stepped

    # --------------------------------------------------------- preemption --
    def _note_progress(self, name: str, ex: StreamExecutor):
        """Stall detection: a stream whose active slots show the same
        device positions across consecutive frontier drains is making no
        forward progress (hung/frozen channel) — evict it so its slots
        relieve the global pressure and healthy tenants keep serving."""
        marker = ex.progress_marker()
        if marker != self._progress[name] or not ex.slots.active_mask().any():
            self._stalled[name] = 0
        else:
            self._stalled[name] += 1
            if self._stalled[name] > self._stall_hwm[name]:
                self._stall_hwm[name] = self._stalled[name]
        self._progress[name] = marker
        if self.stall_limit is not None and \
                self._stalled[name] >= self.stall_limit and \
                ex.slots.active_mask().any() and \
                name not in self._unevictable:
            try:
                self.preempt(name)
            except PreemptionUnsupportedError:
                # a pinned-prefill-shape (replay) stream cannot resume
                # evicted prefixes — leave it in place rather than abort
                # serving for every healthy tenant; never retry
                self._unevictable.add(name)
                self.counters["eviction_unsupported"] += 1
                if self.tracer:
                    self.tracer.instant("sched.eviction_unsupported", "sched",
                                        stream=name)

    def preempt(self, name: str) -> List[int]:
        """Evict a stream's active requests back to its pending queue; the
        slots return to the pool (global slot pressure relief) and the
        stream re-admits when the scheduler next reaches it."""
        ex = self.stream(name)
        evicted = ex.preempt()
        if evicted:
            self.counters["preemptions"] += 1
            self._stalled[name] = 0
            if self.tracer:
                self.tracer.instant("sched.preempt", "sched", stream=name,
                                    evicted=len(evicted))
        return evicted

    # ---------------------------------------------------------------- run --
    def run(self, max_blocks: int = 10_000,
            validate_every: Optional[int] = None
            ) -> Dict[str, Dict[int, List[int]]]:
        """Serve every stream until drained; final frontier drain included.
        Returns ``{stream: {rid: tokens}}``."""
        b = 0
        while self.has_work() and b < max_blocks:
            b += self.step(validate_every)
        for name, ex in self.streams.items():
            self.frontier.drain(ex)
            self._blocks_since_drain[name] = 0
        return {name: ex.outputs() for name, ex in self.streams.items()}

    # ---------------------------------------------------------- reporting --
    def stats(self) -> dict:
        """Public scheduler stats: preempt/evict counts plus the per-stream
        stall state the preemption policy runs on — the stall high-water
        mark answers "how close did this tenant come to eviction".  Shape
        is pinned by ``repro.obs.schema.check_scheduler_stats``."""
        return {
            "preemptions": int(self.counters["preemptions"]),
            "eviction_unsupported": int(self.counters["eviction_unsupported"]),
            "live_slots": self.live_slots(),
            "max_live_slots": self.max_live_slots,
            "stall_limit": self.stall_limit,
            "streams": {
                name: {
                    "stalled": int(self._stalled[name]),
                    "stall_hwm": int(self._stall_hwm[name]),
                    "unevictable": name in self._unevictable,
                    "evicted_requests": int(ex.stats["evicted_requests"]),
                    "admissions_deferred":
                        int(ex.stats["admissions_deferred"]),
                } for name, ex in self.streams.items()
            },
        }

    def aggregate_stats(self) -> collections.Counter:
        total = collections.Counter(self.counters)
        for name, ex in self.streams.items():
            for k, v in ex.stats.items():
                total[f"{name}.{k}"] = v
        total.update({f"frontier.{k}": v
                      for k, v in self.frontier.stats.items()})
        return total
