"""Layered serving stack: Scheduler -> StreamExecutor(s) -> CommitFrontier
over ExecutionChannels (repro.core.channel).  ``Engine`` is the thin
single-stream facade."""
from repro.serving.cache import SlotTable
from repro.serving.engine import Engine, cache_batch_axes_for
from repro.serving.executor import (PreemptionUnsupportedError, Request,
                                    StreamExecutor)
from repro.serving.frontier import CommitFrontier
from repro.serving.scheduler import Scheduler, UnknownStreamError

__all__ = ["Engine", "Scheduler", "StreamExecutor", "CommitFrontier",
           "SlotTable", "Request", "cache_batch_axes_for",
           "PreemptionUnsupportedError", "UnknownStreamError"]
