"""JAX version-compat seam.

The repo targets current JAX (``jax.sharding.AxisType``, ``jax.set_mesh``)
but must also run on 0.4.x containers that predate both.  Every mesh
construction / activation goes through these two helpers so the rest of the
codebase never branches on the JAX version.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh, with Auto axis types when the installed JAX has them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh or legacy ctx)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on current JAX, a per-device
    list of dicts on 0.4.x."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
