"""Data pipeline: deterministic synthetic stream + memmap token files.

The cursor is METASTATE (a handful of ints) — checkpoints inline it, and
restart resumes the exact batch sequence (replay-deterministic, which the
CODY rollback path relies on).  Sharded loading: each DP shard reads its
slice; a prefetch thread keeps one batch ahead; work-stealing hook for
straggling hosts.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict

import numpy as np


class SyntheticLM:
    """Deterministic token stream: batch contents are a pure function of
    (seed, step) — restartable from the cursor alone."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.step = 0

    def meta(self) -> Dict[str, int]:
        return {"cursor_step": self.step, "cursor_seed": self.seed}

    def restore(self, meta: Dict[str, int]):
        self.step = int(meta["cursor_step"])
        self.seed = int(meta["cursor_seed"])

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) ^ self.step)
        toks = rng.integers(3, self.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenFile:
    """Memmap-backed contiguous token corpus (one u32 per token)."""

    def __init__(self, path: str, batch: int, seq: int, offset: int = 0):
        self.arr = np.memmap(path, dtype=np.uint32, mode="r")
        self.batch, self.seq = batch, seq
        self.pos = offset

    def meta(self):
        return {"cursor_pos": self.pos}

    def restore(self, meta):
        self.pos = int(meta["cursor_pos"])

    def next_batch(self):
        need = self.batch * (self.seq + 1)
        if self.pos + need > len(self.arr):
            self.pos = 0
        flat = np.asarray(self.arr[self.pos:self.pos + need], dtype=np.int32)
        self.pos += need
        toks = flat.reshape(self.batch, self.seq + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """One-batch-ahead prefetch thread with a steal() hook for straggler
    mitigation (a slow host can hand its slice to a peer)."""

    def __init__(self, source, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while not self._stop.is_set():
            try:
                self.q.put(self.source.next_batch(), timeout=0.1)
            except queue.Full:
                continue

    def next_batch(self):
        return self.q.get()

    def steal(self):
        """Give away the prefetched batch (straggler work-stealing)."""
        try:
            return self.q.get_nowait()
        except queue.Empty:
            return None

    def close(self):
        self._stop.set()
        self._t.join(timeout=1.0)
