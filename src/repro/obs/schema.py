"""Report/artifact schema checks — observability fields can't silently die.

PR 7's motivating bug: ``NetworkEmulator.snapshot()`` silently dropped
``async_trips``/``collapsed_spins``, so ``Workspace.report()["net"]``
under-reported async and collapsed traffic for two PRs with no test
noticing.  This module pins the shapes:

  * ``check_workspace_report`` — the ``Workspace.report()`` contract
    (net / registry / sessions / replays / metrics / schedulers);
  * ``check_bench_file`` — per-``BENCH_*.json`` required keys plus the
    acceptance FLAGS each artifact asserts about itself (bit-exactness,
    monotone ladders, trace attribution): a flag that flips to False
    fails the check, so CI catches regressions, not just vanished keys;
  * a CLI for the CI step::

        PYTHONPATH=src python -m repro.obs.schema BENCH_*.json TRACE_*.json
"""
from __future__ import annotations

import json
import os
import sys

from repro.obs.metrics import QUANTILE_KEYS


class SchemaError(ValueError):
    """A report/artifact is missing required observability fields (or an
    acceptance flag it declares about itself is False)."""


def _require(d: dict, keys, where: str) -> None:
    if not isinstance(d, dict):
        raise SchemaError(f"{where}: expected a dict, got {type(d).__name__}")
    missing = [k for k in keys if k not in d]
    if missing:
        raise SchemaError(f"{where}: missing fields {missing}")


def _flags(d: dict, keys, where: str) -> None:
    _require(d, keys, where)
    bad = [k for k in keys if d[k] is not True]
    if bad:
        raise SchemaError(f"{where}: acceptance flags not True: {bad}")


NET_KEYS = ("time_s", "round_trips", "async_trips", "bytes_sent",
            "bytes_received", "collapsed_spins", "bytes")
SESSION_KEYS = ("net", "passes", "virtual_time_s", "blocking_round_trips",
                "async_round_trips", "bytes_sent", "bytes_received", "jobs",
                "ops_executed", "per_pass")
REPLAY_KEYS = ("net", "passes", "virtual_time_s", "blocking_round_trips",
               "collapsed_spins", "dispatches", "plan_ops", "jobs",
               "per_pass")
HIST_KEYS = ("count", "sum", "min", "max") + QUANTILE_KEYS
SCHED_KEYS = ("preemptions", "eviction_unsupported", "live_slots", "streams")
SCHED_STREAM_KEYS = ("stalled", "stall_hwm", "unevictable",
                     "evicted_requests", "admissions_deferred")
FLEET_KEYS = ("name", "policy", "tick_s", "ticks", "virtual_time_s",
              "arrivals", "served", "failed", "migrations", "balancer",
              "autoscale", "replicas")
FLEET_BALANCER_KEYS = ("policy", "queue_limit", "queue_depth", "offered",
                       "placed", "rejected", "queue_hwm")
FLEET_REPLICA_KEYS = ("name", "region", "boot_virtual_s", "ready_at",
                      "draining", "retired", "served", "outstanding")
STORE_KEYS = ("chunk_reads", "puts", "gets", "cache", "read_replicas")
CACHE_KEYS = ("max_bytes", "nbytes", "entries", "hits", "misses",
              "evictions")
CAMPAIGN_KEYS = ("name", "devices", "variants", "recorded",
                 "skipped_published", "skipped_leased", "share_history",
                 "tick_s", "ticks", "virtual_time_s",
                 "sum_record_virtual_s", "publishes", "compiles",
                 "artifact_reuses", "speculation", "per_device")
CAMPAIGN_DEVICE_KEYS = ("name", "hw_class", "net", "recorded",
                        "busy_virtual_s", "blocking_round_trips", "spec")
ATTEST_KEYS = ("epoch", "log_size", "root", "quotes", "proofs_verified",
               "proof_bytes")


def check_histogram_summary(s: dict, where: str = "histogram") -> dict:
    _require(s, HIST_KEYS, where)
    return s


def check_scheduler_stats(s: dict, where: str = "scheduler") -> dict:
    _require(s, SCHED_KEYS, where)
    for name, row in s["streams"].items():
        _require(row, SCHED_STREAM_KEYS, f"{where}.streams[{name}]")
    return s


def check_fleet_stats(s: dict, where: str = "fleet") -> dict:
    """Validate one ``ReplicaPool.stats()`` dict; returns ``s``."""
    _require(s, FLEET_KEYS, where)
    _require(s["balancer"], FLEET_BALANCER_KEYS, f"{where}.balancer")
    _require(s["autoscale"], ("enabled", "scale_ups", "retired"),
             f"{where}.autoscale")
    for r in s["replicas"]:
        _require(r, FLEET_REPLICA_KEYS,
                 f"{where}.replicas[{r.get('name')}]")
    return s


def check_registry_store_stats(s: dict,
                               where: str = "registry_store") -> dict:
    """Validate ``report()["registry_store"]`` (LRU cache counters and
    regional read-replica summaries)."""
    _require(s, STORE_KEYS, where)
    if s["cache"] is not None:
        _require(s["cache"], CACHE_KEYS, f"{where}.cache")
    for rr in s["read_replicas"]:
        _require(rr, ("region", "chunk_pulls", "chunk_pull_bytes",
                      "ensure_passthrough", "proofs_relayed", "cache"),
                 f"{where}.read_replicas[{rr.get('region')}]")
        _require(rr["cache"], CACHE_KEYS,
                 f"{where}.read_replicas[{rr.get('region')}].cache")
    return s


def check_campaign_stats(s: dict, where: str = "campaign") -> dict:
    """Validate one ``RecordCampaign.stats()`` dict; returns ``s``."""
    _require(s, CAMPAIGN_KEYS, where)
    _require(s["speculation"], ("predicts", "hits", "records", "hit_rate",
                                "shared"), f"{where}.speculation")
    for d in s["per_device"]:
        _require(d, CAMPAIGN_DEVICE_KEYS,
                 f"{where}.per_device[{d.get('name')}]")
        _require(d["spec"], ("predict", "hit", "record"),
                 f"{where}.per_device[{d.get('name')}].spec")
    return s


def check_workspace_report(rep: dict) -> dict:
    """Validate the full ``Workspace.report()`` shape; returns ``rep``."""
    _require(rep, ("net", "registry_client", "registry_service", "sessions",
                   "replays", "replayer_stats", "metrics", "schedulers",
                   "fleet", "campaigns", "registry_store", "attest"),
             "report")
    if rep["attest"] is not None:
        _require(rep["attest"], ATTEST_KEYS, "report.attest")
    if rep["net"] is not None:
        _require(rep["net"], NET_KEYS, "report.net")
    for i, s in enumerate(rep["sessions"]):
        _require(s, SESSION_KEYS, f"report.sessions[{i}]")
    for i, r in enumerate(rep["replays"]):
        _require(r, REPLAY_KEYS, f"report.replays[{i}]")
    _require(rep["metrics"], ("counters", "histograms"), "report.metrics")
    for k, h in rep["metrics"]["histograms"].items():
        check_histogram_summary(h, f"report.metrics.histograms[{k}]")
    for i, s in enumerate(rep["schedulers"]):
        check_scheduler_stats(s, f"report.schedulers[{i}]")
    for i, s in enumerate(rep["fleet"]):
        check_fleet_stats(s, f"report.fleet[{i}]")
    for i, s in enumerate(rep["campaigns"]):
        check_campaign_stats(s, f"report.campaigns[{i}]")
    check_registry_store_stats(rep["registry_store"],
                               "report.registry_store")
    return rep


# ------------------------------------------------------- bench artifacts --
def _check_multitenant(d: dict) -> None:
    _require(d, ("archs", "solo", "multi", "frontier", "scheduler",
                 "bit_exact_vs_solo", "frontier_only_syncs"), "multitenant")
    for section in ("solo", "multi"):
        for row in d[section]:
            _require(row, ("stream", "tokens", "host_syncs",
                           "syncs_per_token", "latency_quantiles"),
                     f"multitenant.{section}[{row.get('stream')}]")
            _require(row["latency_quantiles"], QUANTILE_KEYS,
                     f"multitenant.{section}[{row.get('stream')}]"
                     ".latency_quantiles")
    check_scheduler_stats(d["scheduler"], "multitenant.scheduler")
    _flags(d, ("bit_exact_vs_solo", "frontier_only_syncs"), "multitenant")


def _check_recording(d: dict) -> None:
    _require(d, ("rows", "wifi_virtual_times_s", "trace_attribution"),
             "recording")
    for row in d["rows"]:
        _require(row, ("stack", "net", "virtual_time_s", "blocking_rts",
                       "trace_attribution"), f"recording[{row.get('stack')}]")
    _flags(d, ("monotone_virtual_time", "all_passes_ge_90pct_below_naive",
               "bit_exact_vs_legacy", "verifies_under_key",
               "trace_attributed_ge_95pct"), "recording")


def _check_replay(d: dict) -> None:
    _require(d, ("native_rows", "ablation"), "replay")
    _flags(d, ("replay_not_slower_than_native", "monotone_virtual_time",
               "bit_exact_vs_naive_replay", "bit_exact_vs_live"), "replay")


def _check_registry(d: dict) -> None:
    _require(d, ("rows", "record_virtual_s", "delta_publish_wire_bytes"),
             "registry")
    _flags(d, ("warm_zero_recording_rts", "warm_reduction_ge_80pct",
               "delta_wire_lt_full"), "registry")


def _check_fleet(d: dict) -> None:
    _require(d, ("tenants", "traffic", "policies", "registry_boot"),
             "fleet")
    _require(d["traffic"], ("seed", "horizon_s", "burst_every_s",
                            "burst_len_s", "burst_x", "arrivals"),
             "fleet.traffic")
    if len(d["policies"]) < 2:
        raise SchemaError("fleet: need >= 2 placement policies, got "
                          f"{len(d['policies'])}")
    for row in d["policies"]:
        where = f"fleet.policies[{row.get('policy')}]"
        _require(row, ("policy", "per_tenant", "pool"), where)
        for tenant, tr in row["per_tenant"].items():
            _require(tr, ("served", "latency_quantiles"),
                     f"{where}.per_tenant[{tenant}]")
            _require(tr["latency_quantiles"], QUANTILE_KEYS,
                     f"{where}.per_tenant[{tenant}].latency_quantiles")
        check_fleet_stats(row["pool"], f"{where}.pool")
    _require(d["registry_boot"], ("cold_boot_virtual_s",
                                  "warm_boot_virtual_s", "reduction_pct"),
             "fleet.registry_boot")
    _flags(d, ("bit_exact_vs_solo", "warm_boot_cheaper_than_cold",
               "warm_boot_reduction_ge_80pct"), "fleet")


def _check_fanout(d: dict) -> None:
    _require(d, ("net", "variants", "device_ladder", "serial",
                 "speculation", "reduction_at_4_devices_pct"), "fanout")
    _require(d["serial"], ("sessions", "virtual_time_s"), "fanout.serial")
    if len(d["device_ladder"]) < 3:
        raise SchemaError("fanout: need a >= 3-rung device ladder, got "
                          f"{len(d['device_ladder'])}")
    for row in d["device_ladder"]:
        where = f"fanout.device_ladder[{row.get('devices')}]"
        _require(row, ("devices", "virtual_time_s", "campaign"), where)
        check_campaign_stats(row["campaign"], f"{where}.campaign")
    _require(d["speculation"], ("shared_hit_rate", "cold_hit_rate"),
             "fanout.speculation")
    _flags(d, ("monotone_virtual_time", "fanout_reduction_ge_70pct",
               "bit_exact_vs_serial", "shared_spec_hit_ge_cold"), "fanout")


def _check_decode(d: dict) -> None:
    _require(d, ("depths", "replay_vs_live"), "decode")
    _flags(d, ("identical_streams_across_depths",), "decode")


def _check_attest(d: dict) -> None:
    _require(d, ("proof_ladder", "verify_overhead", "split_view", "quote"),
             "attest")
    if len(d["proof_ladder"]) < 3:
        raise SchemaError("attest: need a >= 3-rung proof-size ladder, got "
                          f"{len(d['proof_ladder'])}")
    for row in d["proof_ladder"]:
        _require(row, ("entries", "proof_hashes", "proof_wire_bytes",
                       "log2_bound"), f"attest.proof_ladder[{row.get('entries')}]")
    _require(d["verify_overhead"], ("warm_fetch_unverified_s",
                                    "warm_fetch_verified_s", "overhead_pct",
                                    "proof_bytes"), "attest.verify_overhead")
    _require(d["quote"], ("bound_fields", "perturbations_rejected"),
             "attest.quote")
    _flags(d, ("split_view_detected", "verify_overhead_le_5pct",
               "offline_verifier_no_model_imports",
               "proof_growth_sublinear"), "attest")


def _check_trace(d: dict) -> None:
    _require(d, ("traceEvents",), "trace")
    if not isinstance(d["traceEvents"], list) or not d["traceEvents"]:
        raise SchemaError("trace: traceEvents must be a non-empty list")


BENCH_CHECKS = {
    "BENCH_multitenant.json": _check_multitenant,
    "BENCH_recording.json": _check_recording,
    "BENCH_replay.json": _check_replay,
    "BENCH_registry.json": _check_registry,
    "BENCH_decode.json": _check_decode,
    "BENCH_fleet.json": _check_fleet,
    "BENCH_fanout.json": _check_fanout,
    "BENCH_attest.json": _check_attest,
}


def check_bench_file(path: str) -> str:
    base = os.path.basename(path)
    with open(path) as f:
        data = json.load(f)
    if base in BENCH_CHECKS:
        BENCH_CHECKS[base](data)
        return f"schema ok: {base}"
    if base.startswith("TRACE"):
        _check_trace(data)
        return f"schema ok: {base} ({len(data['traceEvents'])} events)"
    raise SchemaError(f"no schema registered for {base}")


def main(argv=None) -> int:
    paths = list(sys.argv[1:] if argv is None else argv)
    if not paths:
        print("usage: python -m repro.obs.schema BENCH_*.json TRACE_*.json")
        return 2
    for p in paths:
        print(check_bench_file(p))
    return 0


if __name__ == "__main__":
    sys.exit(main())


__all__ = ["SchemaError", "check_workspace_report", "check_bench_file",
           "check_histogram_summary", "check_scheduler_stats",
           "check_fleet_stats", "check_campaign_stats",
           "check_registry_store_stats", "main"]
