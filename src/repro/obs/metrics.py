"""Counter/histogram metrics registry — tail latencies, not just totals.

The serving stack's `collections.Counter` stats answer "how many"; the
fleet-serving story (ROADMAP: per-tenant p50/p99/p99.9 SLOs) needs "how
slow at the tail".  ``Metrics`` is the one registry both live on:
named counters and histograms with label sets (``stream=...``), exact
nearest-rank quantiles, and a stable ``snapshot()`` schema that
``Workspace.report()`` and the benchmark artifacts are checked against
(``repro.obs.schema``) so report fields can't silently vanish.

Observations are stored exactly (these are bench/serving-scale series,
thousands of points, not production firehoses); quantiles are
nearest-rank on a sorted copy, so p50/p99/p99.9 are actual observed
values — no interpolation surprises in the artifacts.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

QUANTILE_KEYS = ("p50", "p99", "p999")
_QUANTILES = {"p50": 0.50, "p99": 0.99, "p999": 0.999}


def metric_key(name: str, labels: dict) -> str:
    """Canonical flattened series name: ``name{k=v,...}`` with labels
    sorted — the snapshot/schema key format."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """A monotonically increasing named count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Histogram:
    """Exact-observation histogram with nearest-rank quantiles."""

    __slots__ = ("_vals",)

    def __init__(self):
        self._vals: List[float] = []

    def observe(self, x: float) -> None:
        self._vals.append(float(x))

    @property
    def count(self) -> int:
        return len(self._vals)

    @property
    def sum(self) -> float:
        return float(math.fsum(self._vals))

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile (an actual observed value); 0.0 when no
        observations have been made."""
        if not self._vals:
            return 0.0
        if not 0.0 < q <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {q}")
        v = sorted(self._vals)
        return v[min(len(v) - 1, max(0, math.ceil(q * len(v)) - 1))]

    def summary(self) -> dict:
        """Stable-shape summary: every key always present (zeros when
        empty) so downstream schemas never see missing fields."""
        out = {"count": self.count,
               "sum": round(self.sum, 6),
               "min": round(min(self._vals), 6) if self._vals else 0.0,
               "max": round(max(self._vals), 6) if self._vals else 0.0}
        for k, q in _QUANTILES.items():
            out[k] = round(self.quantile(q), 6)
        return out


class Metrics:
    """The registry: ``counter()``/``histogram()`` create-or-return named
    series; ``snapshot()`` renders the whole registry in the one shape
    the schema checker pins."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._histograms: Dict[str, Histogram] = {}

    # ------------------------------------------------------------- series --
    def counter(self, name: str, **labels) -> Counter:
        return self._counters.setdefault(metric_key(name, labels), Counter())

    def histogram(self, name: str, **labels) -> Histogram:
        return self._histograms.setdefault(metric_key(name, labels),
                                           Histogram())

    def get_histogram(self, name: str, **labels) -> Optional[Histogram]:
        """Lookup without creating (reporting paths must not mint empty
        series)."""
        return self._histograms.get(metric_key(name, labels))

    def quantiles(self, name: str, **labels) -> Optional[dict]:
        """p50/p99/p999 for one series, or None if it was never observed
        — the per-stream latency block the multitenant bench reports."""
        h = self.get_histogram(name, **labels)
        if h is None or h.count == 0:
            return None
        return {k: round(h.quantile(q), 6) for k, q in _QUANTILES.items()}

    # ----------------------------------------------------------- snapshot --
    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._histograms.items())},
        }


__all__ = ["Metrics", "Counter", "Histogram", "metric_key", "QUANTILE_KEYS"]
