"""repro.obs — virtual-time tracing, metrics, and report schemas.

One observability layer for the whole stack: ``Tracer`` (deterministic
virtual-clock spans, Perfetto-loadable export), ``Metrics``
(counter/histogram registry with p50/p99/p99.9 summaries), and the
schema checks that pin ``Workspace.report()`` / ``BENCH_*.json`` shapes.
"""
from repro.obs.metrics import (Counter, Histogram, Metrics, QUANTILE_KEYS,
                               metric_key)
from repro.obs.schema import (SchemaError, check_bench_file,
                              check_workspace_report)
from repro.obs.trace import NULL, NullTracer, Tracer, traced

__all__ = [
    "Tracer", "NullTracer", "NULL", "traced",
    "Metrics", "Counter", "Histogram", "metric_key", "QUANTILE_KEYS",
    "SchemaError", "check_workspace_report", "check_bench_file",
]
