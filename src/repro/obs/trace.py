"""Virtual-time tracing — the evidence layer for every headline number.

Every claim this repro makes (95% fewer recording delays, replay 25%
faster than native, frontier-only host syncs) is an *attribution* claim
about where round trips and virtual time go.  The ``Tracer`` turns the
scattered counters into one timeline: spans and instant events stamped
on the **deterministic virtual clock** (``NetworkEmulator.virtual_time_s``
— wall time rides along as secondary metadata), exported as Chrome
trace-event JSON that Perfetto / ``chrome://tracing`` loads directly.

Design constraints, in order:

  * **Deterministic.**  Two traced runs of the same workload produce
    byte-identical traces once wall timestamps are stripped
    (``to_json(strip_wall=True)``) — the replay-side analogue of the
    bit-exactness flags the benchmarks pin.  Nothing in here calls a
    nondeterministic source except ``time.time()`` for the secondary
    wall fields.
  * **Zero-cost when off.**  ``NULL`` (a falsy ``NullTracer``) is what
    every component holds by default; call sites guard hot paths with
    ``if tracer:`` or the ``traced()`` helper.  Tracing never mutates an
    emulator, a session, or a stats counter — it only *reads* the
    virtual clock — so all existing accounting is bit-identical whether
    tracing is on, off, or absent.
  * **Multi-clock.**  Components that own their own emulator (a record
    session, a replay plan executor, a registry client with a private
    link) enter a ``clock_scope(netem)``: their events are stamped by
    *that* emulator's virtual clock, rebased onto the trace's high-water
    mark so consecutive sessions lay out end-to-end instead of piling
    up at t=0.

Event vocabulary (Chrome trace phases): ``X`` complete spans (duration =
virtual time elapsed inside), ``i`` instants, ``C`` counter samples.
Tracks (one Perfetto thread lane each): ``record``, ``replay``,
``registry``, ``serve.<stream>``, ``sched``.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Callable, List, Optional


class _NullSpan:
    """Reusable no-op context manager (the body still runs)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Falsy do-nothing tracer: ``if tracer:`` guards make tracing
    provably zero-cost when off.  Every component defaults to ``NULL``
    so call sites never need None checks."""

    __slots__ = ()
    events: tuple = ()

    def __bool__(self) -> bool:
        return False

    def now(self) -> float:
        return 0.0

    def span(self, name, track="main", **args):
        return _NULL_SPAN

    def clock_scope(self, netem):
        return _NULL_SPAN

    def instant(self, name, track="main", **args) -> None:
        pass

    def counter(self, name, value, track="main") -> None:
        pass

    def mark(self) -> int:
        return 0


NULL = NullTracer()


def traced(tracer, name, track="main", **args):
    """One-line guard helper: a real span when tracing is on, the shared
    null context manager when off — so hot paths pay one truthiness
    check and nothing else."""
    return tracer.span(name, track, **args) if tracer else _NULL_SPAN


class Tracer:
    """Deterministic virtual-time span/event recorder.

    ``clock`` is a zero-arg callable returning the current virtual time
    in seconds (typically ``lambda: netem.virtual_time_s``); omitted, the
    base clock is a constant 0 — spans still nest and count, with wall
    time as the only moving timestamp (kept out of the deterministic
    export).
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.events: List[dict] = []
        self._clocks: List[Callable[[], float]] = [
            clock if clock is not None else (lambda: 0.0)]
        self._hwm = 0.0                 # latest virtual timestamp emitted
        self._t0_wall = time.time()

    def __bool__(self) -> bool:
        return True

    # --------------------------------------------------------------- time --
    def now(self) -> float:
        return float(self._clocks[-1]())

    @contextlib.contextmanager
    def clock_scope(self, netem):
        """Stamp events inside this scope with ``netem``'s virtual clock,
        rebased onto the trace high-water mark (sessions with private
        emulators lay out sequentially instead of overlapping at 0).
        ``netem=None`` is a no-op scope."""
        if netem is None:
            yield self
            return
        base = max(self.now(), self._hwm) - float(netem.virtual_time_s)
        self._clocks.append(lambda: base + float(netem.virtual_time_s))
        try:
            yield self
        finally:
            self._clocks.pop()

    # ------------------------------------------------------------- events --
    def _emit(self, ev: dict) -> None:
        end = ev["ts"] + ev.get("dur", 0.0)
        if end > self._hwm:
            self._hwm = end
        self.events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, track: str = "main", **args):
        """A complete span: virtual-time begin/duration measured around
        the body; wall time recorded as secondary metadata."""
        t0 = self.now()
        w0 = time.time()
        try:
            yield self
        finally:
            self._emit({"name": name, "ph": "X", "track": track,
                        "ts": t0, "dur": self.now() - t0,
                        "wall_s": w0 - self._t0_wall,
                        "wall_dur_s": time.time() - w0,
                        "args": args})

    def instant(self, name: str, track: str = "main", **args) -> None:
        self._emit({"name": name, "ph": "i", "track": track,
                    "ts": self.now(),
                    "wall_s": time.time() - self._t0_wall,
                    "args": args})

    def counter(self, name: str, value, track: str = "main") -> None:
        self._emit({"name": name, "ph": "C", "track": track,
                    "ts": self.now(), "value": float(value),
                    "wall_s": time.time() - self._t0_wall,
                    "args": {}})

    def mark(self) -> int:
        """Event-index bookmark; pass as ``since=`` to scope analysis to
        everything recorded after it (per-scenario attribution)."""
        return len(self.events)

    # ----------------------------------------------------------- analysis --
    def spans(self, track: Optional[str] = None, since: int = 0
              ) -> List[dict]:
        return [e for e in self.events[since:]
                if e["ph"] == "X" and (track is None or e["track"] == track)]

    def attributed_s(self, track: Optional[str] = None, since: int = 0
                     ) -> float:
        """Virtual time covered by named spans on ``track``: the measure
        of the union of their ``[ts, ts+dur)`` intervals, so nested and
        overlapping spans never double-count.  Comparing this against a
        session's ``virtual_time_s`` answers "how much of the bill is
        attributed to a named phase?"."""
        ivals = sorted((e["ts"], e["ts"] + e["dur"])
                       for e in self.spans(track, since) if e["dur"] > 0)
        total, end = 0.0, float("-inf")
        for lo, hi in ivals:
            if lo > end:
                total += hi - lo
                end = hi
            elif hi > end:
                total += hi - end
                end = hi
        return total

    def summary(self, top: Optional[int] = None, since: int = 0
                ) -> List[dict]:
        """Per-(track, name) span totals, sorted by virtual time spent —
        the "where did the time go" table."""
        agg: dict = {}
        for e in self.spans(since=since):
            row = agg.setdefault((e["track"], e["name"]),
                                 {"track": e["track"], "name": e["name"],
                                  "count": 0, "virtual_s": 0.0,
                                  "wall_s": 0.0})
            row["count"] += 1
            row["virtual_s"] += e["dur"]
            row["wall_s"] += e["wall_dur_s"]
        rows = sorted(agg.values(),
                      key=lambda r: (-r["virtual_s"], r["track"], r["name"]))
        for r in rows:
            r["virtual_s"] = round(r["virtual_s"], 6)
            r["wall_s"] = round(r["wall_s"], 6)
        return rows[:top] if top is not None else rows

    def format_summary(self, top: int = 15, since: int = 0) -> str:
        rows = self.summary(top=top, since=since)
        if not rows:
            return "(no spans recorded)"
        w = max(len(f"{r['track']}/{r['name']}") for r in rows)
        lines = [f"{'span'.ljust(w)}  {'count':>6}  {'virtual_s':>10}  "
                 f"{'wall_s':>8}"]
        for r in rows:
            lines.append(f"{(r['track'] + '/' + r['name']).ljust(w)}  "
                         f"{r['count']:>6}  {r['virtual_s']:>10.4f}  "
                         f"{r['wall_s']:>8.3f}")
        return "\n".join(lines)

    # ------------------------------------------------------------- export --
    def chrome_trace(self, strip_wall: bool = False) -> dict:
        """Chrome trace-event / Perfetto-loadable JSON object.  Virtual
        seconds become microseconds (``ts``/``dur``); wall timestamps ride
        in ``args`` unless ``strip_wall`` (the determinism test strips
        them and demands byte-identical output across runs)."""
        tids: dict = {}
        out: List[dict] = []
        for ev in self.events:
            tid = tids.setdefault(ev["track"], len(tids) + 1)
            e = {"name": ev["name"], "ph": ev["ph"], "pid": 0, "tid": tid,
                 "cat": ev["track"], "ts": round(ev["ts"] * 1e6, 3),
                 "args": dict(ev["args"])}
            if ev["ph"] == "X":
                e["dur"] = round(ev["dur"] * 1e6, 3)
            elif ev["ph"] == "i":
                e["s"] = "t"
            elif ev["ph"] == "C":
                e["args"] = {"value": ev["value"]}
            if not strip_wall:
                e["args"]["wall_s"] = round(ev["wall_s"], 6)
                if "wall_dur_s" in ev:
                    e["args"]["wall_dur_s"] = round(ev["wall_dur_s"], 6)
            out.append(e)
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                 "args": {"name": track}}
                for track, tid in sorted(tids.items(), key=lambda kv: kv[1])]
        return {"traceEvents": meta + out, "displayTimeUnit": "ms",
                "metadata": {"clock": "virtual"}}

    def to_json(self, strip_wall: bool = False) -> str:
        return json.dumps(self.chrome_trace(strip_wall=strip_wall),
                          sort_keys=True, separators=(",", ":"))

    def dump(self, path: str, strip_wall: bool = False) -> str:
        with open(path, "w") as f:
            f.write(self.to_json(strip_wall=strip_wall))
        return path


__all__ = ["Tracer", "NullTracer", "NULL", "traced"]
