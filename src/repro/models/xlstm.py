"""xLSTM blocks: mLSTM (chunkwise-parallel matrix memory) and sLSTM
(scalar memory, exponential gating with stabilizer state, lax.scan).

The mLSTM uses a GLA-style chunkwise formulation (per-head scalar forget
decay in log space + matrix state), which matches the recurrent decode rule
exactly; the Pallas kernel in repro/kernels/mlstm.py mirrors the intra-chunk
math.  sLSTM is inherently sequential (nonlinear recurrence) -> lax.scan;
its HLO while-loop cost is trip-count-corrected by the roofline analyzer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, apply_norm, norm_schema
from repro.sharding import constrain


def mlstm_dims(cfg):
    d_in = int(cfg.d_model * cfg.xlstm.proj_factor_m)
    nh = cfg.num_heads
    return d_in, nh, d_in // nh


def mlstm_schema(cfg):
    D = cfg.d_model
    d_in, nh, dh = mlstm_dims(cfg)
    return {
        "w_up": ParamSpec((D, 2 * d_in), ("fsdp", "ssm_inner"), D ** -0.5),
        "wq": ParamSpec((d_in, d_in), ("ssm_inner", None), d_in ** -0.5),
        "wk": ParamSpec((d_in, d_in), ("ssm_inner", None), d_in ** -0.5),
        "wv": ParamSpec((d_in, d_in), ("ssm_inner", None), d_in ** -0.5),
        "w_if": ParamSpec((D, 2 * nh), ("fsdp", "ssm_heads"), D ** -0.5),
        "b_if": ParamSpec((2 * nh,), ("ssm_heads",), 0.0, "float32"),
        "norm": norm_schema(d_in),
        "w_down": ParamSpec((d_in, D), ("ssm_inner", "fsdp"), d_in ** -0.5),
    }


def _mlstm_qkvgates(p, x, cfg):
    d_in, nh, dh = mlstm_dims(cfg)
    up = x @ p["w_up"]
    z, h_in = up[..., :d_in], up[..., d_in:]
    shp = x.shape[:-1]
    q = (h_in @ p["wq"]).reshape(*shp, nh, dh) * dh ** -0.5
    k = (h_in @ p["wk"]).reshape(*shp, nh, dh) * dh ** -0.5
    v = (h_in @ p["wv"]).reshape(*shp, nh, dh)
    gates = (x @ p["w_if"]).astype(jnp.float32) + p["b_if"]
    logf = jax.nn.log_sigmoid(gates[..., :nh])       # per-head forget (log)
    logi = gates[..., nh:]                           # input gate (log-space)
    return z, q, k, v, logf, logi


def mlstm_forward(p, x, cfg, rules=None):
    """Chunkwise-parallel mLSTM. x [B,S,D] -> [B,S,D]."""
    B, S, D = x.shape
    d_in, nh, dh = mlstm_dims(cfg)
    from repro.models.ssm import pick_chunk
    Q = pick_chunk(S, cfg.xlstm.chunk)
    nc = S // Q
    z, q, k, v, logf, logi = _mlstm_qkvgates(p, x, cfg)
    if rules is not None:
        q, k, v = (constrain(t, ("batch", None, None, None), rules)
                   for t in (q, k, v))

    c = lambda t: t.reshape(B, nc, Q, *t.shape[2:])
    qc, kc, vc, lf, li = c(q), c(k), c(v), c(logf), c(logi)
    li = jnp.minimum(li, 8.0)                        # bounded exp input gate
    cumf = jnp.cumsum(lf, axis=2)                    # [B,nc,Q,nh]  (<= 0)
    # all exponents below are <= li (cumf decreasing), so no stabilizer state
    wgt = jnp.exp(cumf[:, :, -1:] - cumf + li)       # decay to chunk END
    kbar = kc.astype(jnp.float32) * wgt[..., None]

    # intra-chunk
    scores = jnp.einsum("bcihd,bcjhd->bcijh", qc.astype(jnp.float32),
                        kc.astype(jnp.float32))
    decay = jnp.exp(cumf[:, :, :, None] - cumf[:, :, None, :]
                    + li[:, :, None, :])
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    lmat = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    y_diag = jnp.einsum("bcijh,bcijh,bcjhd->bcihd", scores, lmat,
                        vc.astype(jnp.float32))
    n_diag = jnp.einsum("bcijh,bcjhd->bcihd", lmat, kc.astype(jnp.float32))

    # chunk states  Ck [B,nc,nh,dk,dv], Nk [B,nc,nh,dk]
    states = jnp.einsum("bcjhd,bcjhe->bchde", kbar, vc.astype(jnp.float32))
    nstates = jnp.einsum("bcjhd->bchd", kbar)
    cdecay = jnp.exp(cumf[:, :, -1])                 # [B,nc,nh]

    def comb(a, b):
        d1, s1, n1 = a
        d2, s2, n2 = b
        return (d1 * d2, s1 * d2[..., None, None] + s2, n1 * d2[..., None] + n2)
    dsc, ssc, nsc = jax.lax.associative_scan(
        comb, (cdecay, states, nstates), axis=1)
    h_prev = jnp.concatenate([jnp.zeros_like(ssc[:, :1]), ssc[:, :-1]], 1)
    n_prev = jnp.concatenate([jnp.zeros_like(nsc[:, :1]), nsc[:, :-1]], 1)

    inter_w = jnp.exp(cumf)                          # decay from chunk start
    y_off = jnp.einsum("bcihd,bchde,bcih->bcihe", qc.astype(jnp.float32),
                       h_prev, inter_w)
    n_off = jnp.einsum("bcihd,bchd,bcih->bcih", qc.astype(jnp.float32),
                       n_prev, inter_w)
    y = y_diag + y_off
    n = jnp.einsum("bcihd->bcih", qc.astype(jnp.float32) * n_diag) + n_off
    y = y / jnp.maximum(jnp.abs(n)[..., None], 1.0)
    y = y.reshape(B, S, d_in).astype(x.dtype) * jax.nn.silu(z)
    y = apply_norm(p["norm"], y)
    return y @ p["w_down"], (ssc[:, -1], nsc[:, -1])


def mlstm_init_state(cfg, batch):
    d_in, nh, dh = mlstm_dims(cfg)
    return {"C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32)}


def mlstm_decode(p, x, cfg, state):
    """x [B,1,D] recurrent step."""
    B = x.shape[0]
    d_in, nh, dh = mlstm_dims(cfg)
    z, q, k, v, logf, logi = _mlstm_qkvgates(p, x[:, 0], cfg)
    f = jnp.exp(logf)                                # [B,nh]
    i = jnp.exp(jnp.minimum(logi, 8.0))
    C = state["C"] * f[..., None, None] + i[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    n = state["n"] * f[..., None] + i[..., None] * k.astype(jnp.float32)
    y = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)
    y = y / jnp.maximum(jnp.abs(den)[..., None], 1.0)
    y = y.reshape(B, d_in).astype(x.dtype) * jax.nn.silu(z)
    y = apply_norm(p["norm"], y)
    return (y @ p["w_down"])[:, None], {"C": C, "n": n}


# ------------------------------------------------------------------ sLSTM --
def slstm_schema(cfg):
    D = cfg.d_model
    nh = cfg.num_heads
    dh = D // nh
    F = int(D * cfg.xlstm.proj_factor_s)
    return {
        "w_gates": ParamSpec((D, 4 * D), ("fsdp", "ssm_inner"), D ** -0.5),
        "r_gates": ParamSpec((4, nh, dh, dh), (None, "ssm_heads", None, None),
                             dh ** -0.5),
        "b_gates": ParamSpec((4 * D,), ("ssm_inner",), 0.0, "float32"),
        "norm": norm_schema(D),
        "ffn_w1": ParamSpec((D, F), ("fsdp", "ffn"), D ** -0.5),
        "ffn_w3": ParamSpec((D, F), ("fsdp", "ffn"), D ** -0.5),
        "ffn_w2": ParamSpec((F, D), ("ffn", "fsdp"), F ** -0.5),
    }


def _slstm_cell(p, xg, carry, cfg):
    """xg [B,4D] precomputed input gates; carry = (h, c, n, m) each [B,nh,dh]."""
    nh = cfg.num_heads
    dh = cfg.d_model // nh
    B = xg.shape[0]
    h, c, n, m = carry
    rec = jnp.einsum("bhd,ghde->bghe", h, p["r_gates"].astype(jnp.float32))
    g = xg.reshape(B, 4, nh, dh).astype(jnp.float32) + rec
    zt = jnp.tanh(g[:, 0])
    it = g[:, 1]                                     # log-space input gate
    ft = g[:, 2]                                     # log-space forget gate
    ot = jax.nn.sigmoid(g[:, 3])
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c = f_p * c + i_p * zt
    n = f_p * n + i_p
    h_new = ot * c / jnp.maximum(n, 1.0)
    return h_new, c, n, m_new


def slstm_forward(p, x, cfg, rules=None):
    """x [B,S,D] -> [B,S,D] via lax.scan over time.

    ``xg`` is pinned to batch-only sharding BEFORE the time scan: a
    seq-sharded xg would force a per-timestep all-gather inside the loop
    (measured 37 TB of collectives on xlstm train_4k — EXPERIMENTS.md
    §Perf).  One gather outside the loop instead."""
    B, S, D = x.shape
    nh, dh = cfg.num_heads, D // cfg.num_heads
    xg = (x @ p["w_gates"]).astype(jnp.float32) + p["b_gates"]
    xg = constrain(xg, ("batch", None, None), rules) if rules else xg
    init = tuple(jnp.zeros((B, nh, dh), jnp.float32) for _ in range(4))

    def body(carry, xg_t):
        new = _slstm_cell(p, xg_t, carry, cfg)
        if rules is not None:
            # pin the recurrent state to batch-only sharding: downstream
            # (FFN tp) propagation would otherwise shard dh over 'model'
            # and force a per-timestep all-gather (measured 3.9 TB/step on
            # xlstm train_4k — EXPERIMENTS.md §Perf iter 2)
            new = tuple(constrain(t, ("batch", None, None), rules)
                        for t in new)
        return new, new[0]
    carry, hs = jax.lax.scan(body, init, xg.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    y = constrain(y, ("batch", None, None), rules) if rules else y
    y = apply_norm(p["norm"], y)
    y = jax.nn.silu(y @ p["ffn_w1"]) * (y @ p["ffn_w3"])
    return y @ p["ffn_w2"], carry


def slstm_init_state(cfg, batch):
    nh, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}


def slstm_decode(p, x, cfg, state):
    xg = (x[:, 0] @ p["w_gates"]).astype(jnp.float32) + p["b_gates"]
    carry = (state["h"], state["c"], state["n"], state["m"])
    h, c, n, m = _slstm_cell(p, xg, carry, cfg)
    B, D = x.shape[0], x.shape[-1]
    y = h.reshape(B, D).astype(x.dtype)
    y = apply_norm(p["norm"], y)
    y = jax.nn.silu(y @ p["ffn_w1"]) * (y @ p["ffn_w3"])
    return (y @ p["ffn_w2"])[:, None], {"h": h, "c": c, "n": n, "m": m}
