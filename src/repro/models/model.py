"""Stage-structured unified model.

A model is a list of *stages*; each stage is ``lax.scan`` over ``n``
identical blocks (params stacked on a leading axis).  Compile time is O(1)
in depth; the roofline analyzer multiplies while-body costs by the scan trip
count read from HLO ``known_trip_count``.

Supported block kinds: dense (GQA/SWA, optional parallel-block), moe,
mla_dense / mla_moe (deepseek), enc / dec (whisper), mamba (mamba2),
zamba_group (6 mamba + shared attention block), xlstm_group (5 mLSTM +
1 sLSTM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec
from repro.sharding import constrain


def _maybe_dequant(p):
    """Transparently dequantize int8 serving weights ({'q','s'} leaves) —
    inside the layer-scan body, so only one layer's weights materialize in
    bf16 at a time (repro.serving.quant)."""
    from repro.serving.quant import dequantize
    has_q = any(isinstance(x, dict) and set(x) == {"q", "s"}
                for x in jax.tree.leaves(
                    p, is_leaf=lambda x: isinstance(x, dict) and
                    set(x) == {"q", "s"}))
    return dequantize(p) if has_q else p


@dataclasses.dataclass(frozen=True)
class StageDef:
    kind: str
    n: int


def stack_schema(schema, n: int):
    if n == 1:
        return schema
    return jax.tree.map(
        lambda sp: ParamSpec((n,) + sp.shape, ("stack",) + sp.axes, sp.scale,
                             sp.dtype),
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))


# --------------------------------------------------------------- stages ----
def build_stages(cfg: ModelConfig) -> List[StageDef]:
    if cfg.family == "moe" and cfg.attention == "mla":
        return [StageDef("mla_dense", 1), StageDef("mla_moe", cfg.num_layers - 1)]
    if cfg.family == "moe":
        return [StageDef("moe", cfg.num_layers)]
    if cfg.family == "audio":
        return [StageDef("enc", cfg.encdec.num_encoder_layers),
                StageDef("dec", cfg.num_layers)]
    if cfg.family == "ssm":  # xlstm: groups of 6 (sLSTM at in-group index 3)
        assert cfg.num_layers % 6 == 0
        return [StageDef("xlstm_group", cfg.num_layers // 6)]
    if cfg.family == "hybrid":  # zamba2: groups of (shared_every mamba + shared attn)
        g = cfg.shared_every
        return [StageDef("zamba_group", cfg.num_layers // g)]
    return [StageDef("dense", cfg.num_layers)]


def _moe_shard_mode(cfg) -> str:
    return "expert" if cfg.moe and cfg.moe.num_experts >= 16 else "ffn"


def _block_schema(cfg: ModelConfig, kind: str):
    D = cfg.d_model
    nrm = lambda: L.norm_schema(D, cfg.norm)
    if kind == "dense":
        s = {"ln1": nrm(), "attn": L.gqa_schema(cfg)}
        if cfg.parallel_block:
            s["mlp"] = L.mlp_schema(cfg)
        else:
            s["ln2"] = nrm()
            s["mlp"] = L.mlp_schema(cfg)
        return s
    if kind == "moe":
        return {"ln1": nrm(), "attn": L.gqa_schema(cfg), "ln2": nrm(),
                "moe": MOE.moe_schema(cfg, _moe_shard_mode(cfg))}
    if kind == "mla_dense":
        return {"ln1": nrm(), "attn": L.mla_schema(cfg), "ln2": nrm(),
                "mlp": L.mlp_schema(cfg, cfg.dense_first_layer_d_ff or cfg.d_ff)}
    if kind == "mla_moe":
        return {"ln1": nrm(), "attn": L.mla_schema(cfg), "ln2": nrm(),
                "moe": MOE.moe_schema(cfg, _moe_shard_mode(cfg))}
    if kind == "enc":
        return {"ln1": nrm(), "attn": L.gqa_schema(cfg), "ln2": nrm(),
                "mlp": L.mlp_schema(cfg)}
    if kind == "dec":
        return {"ln1": nrm(), "attn": L.gqa_schema(cfg),
                "lnx": nrm(), "xattn": L.gqa_schema(cfg),
                "ln2": nrm(), "mlp": L.mlp_schema(cfg)}
    if kind == "mamba":
        return {"ln1": nrm(), "mamba": SSM.mamba2_schema(cfg)}
    if kind == "zamba_group":
        return {"mambas": stack_schema(
            {"ln1": nrm(), "mamba": SSM.mamba2_schema(cfg)}, cfg.shared_every)}
    if kind == "xlstm_group":
        return {"m": stack_schema(
            {"ln1": nrm(), "cell": XL.mlstm_schema(cfg)}, 5),
            "s": {"ln1": nrm(), "cell": XL.slstm_schema(cfg)}}
    raise ValueError(kind)


def model_schema(cfg: ModelConfig):
    D, V = cfg.d_model, cfg.vocab_size
    s: Dict[str, Any] = {
        "embed": ParamSpec((V, D), ("vocab", "fsdp"), D ** -0.5),
        "final_norm": L.norm_schema(D, cfg.norm),
        "stages": [stack_schema(_block_schema(cfg, st.kind), st.n)
                   for st in build_stages(cfg)],
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((D, V), ("fsdp", "vocab"), D ** -0.5)
    if cfg.family == "hybrid":  # zamba2 shared attention block (applied per group)
        s["shared"] = {"ln1": L.norm_schema(D, cfg.norm),
                       "attn": L.gqa_schema(cfg), "ln2": L.norm_schema(D, cfg.norm),
                       "mlp": L.mlp_schema(cfg)}
    if cfg.family == "audio":
        s["enc_pos"] = ParamSpec((cfg.encdec.encoder_seq, D), ("seq", "fsdp"), 0.02)
        s["dec_pos"] = ParamSpec((cfg.max_seq, D), ("seq", "fsdp"), 0.02)
    if cfg.family == "vlm":
        s["img_proj"] = ParamSpec((D, D), ("fsdp", None), D ** -0.5)
    return s


def init_params(cfg: ModelConfig, key):
    return L.materialize(model_schema(cfg), key, cfg.dtype)


def abstract_params(cfg: ModelConfig):
    return L.abstract(model_schema(cfg), cfg.dtype)


def param_axes(cfg: ModelConfig):
    return L.axes_tree(model_schema(cfg))


# -------------------------------------------------------------- forward ----
def _block_forward(kind, p, h, cfg, rules, shared=None, enc_out=None):
    """Full-sequence forward for one block. Returns (h, aux_loss, cache_out)."""
    p = _maybe_dequant(p)
    aux = 0.0
    cache_out = ()
    if kind in ("dense", "moe", "enc", "mla_dense", "mla_moe"):
        hn = L.apply_norm(p["ln1"], h, cfg.norm)
        if kind in ("mla_dense", "mla_moe"):
            a, (c_kv, k_rope) = L.mla_attention(p["attn"], hn, cfg, rules=rules)
            cache_out = {"c": c_kv, "kr": k_rope}
        else:
            a, (k, v) = L.gqa_attention(p["attn"], hn, cfg, rules=rules,
                                        causal=(kind != "enc"))
            cache_out = {"k": k, "v": v}
        if cfg.parallel_block:
            m = L.apply_mlp(p["mlp"], hn, cfg, rules)
            h = h + a + m
        else:
            h = h + a
            hn2 = L.apply_norm(p["ln2"], h, cfg.norm)
            if kind in ("moe", "mla_moe"):
                m, aux = MOE.apply_moe(p["moe"], hn2, cfg, rules=rules,
                                       group_size=getattr(cfg, "_moe_group", 0))
            else:
                m = L.apply_mlp(p["mlp"], hn2, cfg, rules)
            h = h + m
    elif kind == "dec":
        hn = L.apply_norm(p["ln1"], h, cfg.norm)
        a, (k, v) = L.gqa_attention(p["attn"], hn, cfg, rules=rules)
        h = h + a
        hx = L.apply_norm(p["lnx"], h, cfg.norm)
        xk = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wk"])
        xv = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wv"])
        a, _ = L.gqa_attention(p["xattn"], hx, cfg, rules=rules, cross_kv=(xk, xv))
        h = h + a
        hn2 = L.apply_norm(p["ln2"], h, cfg.norm)
        h = h + L.apply_mlp(p["mlp"], hn2, cfg, rules)
        cache_out = {"k": k, "v": v, "xk": xk, "xv": xv}
    elif kind == "mamba":
        hn = L.apply_norm(p["ln1"], h, cfg.norm)
        y, cache_out = SSM.mamba2_forward(p["mamba"], hn, cfg, rules)
        h = h + y
    elif kind == "zamba_group":
        m_states = []
        for i in range(cfg.shared_every):
            pm = jax.tree.map(lambda t: t[i], p["mambas"])
            hn = L.apply_norm(pm["ln1"], h, cfg.norm)
            y, stt = SSM.mamba2_forward(pm["mamba"], hn, cfg, rules)
            m_states.append(stt)
            h = h + y
        hn = L.apply_norm(shared["ln1"], h, cfg.norm)
        a, (k, v) = L.gqa_attention(shared["attn"], hn, cfg, rules=rules)
        h = h + a
        hn = L.apply_norm(shared["ln2"], h, cfg.norm)
        h = h + L.apply_mlp(shared["mlp"], hn, cfg, rules)
        cache_out = {"mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *m_states),
                     "attn": {"k": k, "v": v}}
    elif kind == "xlstm_group":
        order = [0, 1, 2, None, 3, 4]  # None -> sLSTM (in-group index 3)
        m_states, s_state = [], None
        for idx in order:
            if idx is None:
                hn = L.apply_norm(p["s"]["ln1"], h, cfg.norm)
                y, s_state = XL.slstm_forward(p["s"]["cell"], hn, cfg, rules)
            else:
                pm = jax.tree.map(lambda t: t[idx], p["m"])
                hn = L.apply_norm(pm["ln1"], h, cfg.norm)
                y, m_states_i = XL.mlstm_forward(pm["cell"], hn, cfg, rules)
                m_states.append({"C": m_states_i[0], "n": m_states_i[1]})
            h = h + y
        hc, cc, nc_, mc = s_state
        cache_out = {"m": jax.tree.map(lambda *xs: jnp.stack(xs), *m_states),
                     "s": {"h": hc, "c": cc, "n": nc_, "m": mc}}
    else:
        raise ValueError(kind)
    h = constrain(h, ("batch", "seq", None), rules) if rules else h
    return h, aux, cache_out


def forward(params, cfg: ModelConfig, batch: Dict[str, Any], rules=None,
            remat: str = "none", collect_cache: bool = False):
    """Full-sequence forward -> (logits [B,S,V], aux_loss[, kv_stacks]).

    batch: tokens [B,S]; audio adds frames [B,enc_S,D]; vlm adds image
    embeds [B,n_img,D] prepended to the text sequence.  With
    ``collect_cache`` the per-block K/V (or final SSM states) are returned
    for prefill-cache assembly (see ``assemble_caches``).
    """
    tokens = batch["tokens"]
    params = {k: (_maybe_dequant(v) if k != "stages" else v)
              for k, v in params.items()}
    h = jnp.take(params["embed"], tokens, axis=0)
    h = constrain(h, ("batch", "seq", None), rules) if rules else h
    n_img = 0
    if cfg.family == "vlm" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(h.dtype) @ params["img_proj"]
        h = jnp.concatenate([img, h], axis=1)
        n_img = img.shape[1]
    enc_out = None
    if cfg.family == "audio":
        h_dec = h + params["dec_pos"][:h.shape[1]].astype(h.dtype)
        enc_out = batch["frames"].astype(h.dtype) + \
            params["enc_pos"].astype(h.dtype)
        h = enc_out  # first stage is the encoder

    stages = build_stages(cfg)
    aux_total = 0.0
    kv_stacks = []
    for st, sp in zip(stages, params["stages"]):
        if cfg.family == "audio" and st.kind == "dec":
            enc_out, h = h, h_dec  # encoder output feeds decoder cross-attn

        def body(carry, pl, _kind=st.kind):
            hh, aux = carry
            hh, a, kv = _block_forward(_kind, pl, hh, cfg, rules,
                                       shared=params.get("shared"),
                                       enc_out=enc_out)
            return (hh, aux + a), (kv if collect_cache else ())
        if remat != "none":
            body = jax.checkpoint(
                body, policy=_remat_policy(remat), static_argnums=())
        if st.n == 1:
            (h, aux_total), kvs = body((h, aux_total), sp)
        else:
            (h, aux_total), kvs = jax.lax.scan(body, (h, aux_total), sp)
        kv_stacks.append(kvs)

    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    if n_img:
        h = h[:, n_img:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head) * cfg.logit_scale
    if rules is not None:
        logits = constrain(logits, ("batch", "seq", "vocab"), rules)
    if collect_cache:
        return logits, aux_total, kv_stacks
    return logits, aux_total


def _remat_policy(name: str):
    pol = jax.checkpoint_policies
    return {"full": pol.nothing_saveable,
            "dots": pol.dots_with_no_batch_dims_saveable,
            "minimal": pol.everything_saveable}[name]


# --------------------------------------------------------------- decode ----
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, enc_S: int = 0):
    """Cache pytree per stage (leading stage-scan axis when n>1)."""
    dt = jnp.dtype(cfg.dtype)
    Hkv, hd = cfg.num_kv_heads, cfg.hd()
    W = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len

    def kv(n, w=None):
        w = w or W
        shape = (batch, w, Hkv, hd) if n == 1 else (n, batch, w, Hkv, hd)
        if cfg.kv_quant:
            sshape = shape[:-1] + (1,)
            return {"k": jnp.zeros(shape, jnp.int8),
                    "k_s": jnp.zeros(sshape, jnp.float32),
                    "v": jnp.zeros(shape, jnp.int8),
                    "v_s": jnp.zeros(sshape, jnp.float32)}
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    caches = []
    for st in build_stages(cfg):
        if st.kind in ("dense", "moe", "enc"):
            caches.append(kv(st.n))
        elif st.kind in ("mla_dense", "mla_moe"):
            m = cfg.mla
            shp = lambda d: ((batch, cache_len, d) if st.n == 1 else
                             (st.n, batch, cache_len, d))
            caches.append({"c": jnp.zeros(shp(m.kv_lora_rank), dt),
                           "kr": jnp.zeros(shp(m.qk_rope_head_dim), dt)})
        elif st.kind == "dec":
            c = kv(st.n)
            xshape = (st.n, batch, enc_S, Hkv, hd)
            c["xk"] = jnp.zeros(xshape, dt)
            c["xv"] = jnp.zeros(xshape, dt)
            caches.append(c)
        elif st.kind == "mamba":
            caches.append(_stack_state(SSM.mamba2_init_state(cfg, batch, dt), st.n))
        elif st.kind == "zamba_group":
            caches.append({
                "mamba": _stack_state(_stack_state(
                    SSM.mamba2_init_state(cfg, batch, dt), cfg.shared_every), st.n),
                "attn": kv(st.n, w=cache_len)})
        elif st.kind == "xlstm_group":
            caches.append({
                "m": _stack_state(_stack_state(XL.mlstm_init_state(cfg, batch), 5), st.n),
                "s": _stack_state(XL.slstm_init_state(cfg, batch), st.n)})
        else:
            raise ValueError(st.kind)
    return caches


def _stack_state(state, n):
    if n == 1:
        return state
    return jax.tree.map(lambda t: jnp.broadcast_to(t[None], (n,) + t.shape), state)


def cache_axes(cfg: ModelConfig):
    """Logical axes mirroring ``init_cache`` (serve: kv_seq -> SP over tp)."""
    kv = ("batch", "kv_seq", "kv_heads", "head_dim")
    st = lambda n, ax: ax if n == 1 else ("stack",) + ax
    kv_entry = lambda n: (
        {"k": st(n, kv), "k_s": st(n, kv), "v": st(n, kv),
         "v_s": st(n, kv)} if cfg.kv_quant else
        {"k": st(n, kv), "v": st(n, kv)})
    mamba_ax = lambda pre: {"ssm": pre + ("batch", "ssm_heads", None, None),
                            "conv": {"x": pre + ("batch", None, "ssm_inner"),
                                     "bc": pre + ("batch", None, None)}}
    axes = []
    for s in build_stages(cfg):
        pre = () if s.n == 1 else ("stack",)
        if s.kind in ("dense", "moe", "enc"):
            axes.append(kv_entry(s.n))
        elif s.kind in ("mla_dense", "mla_moe"):
            axes.append({"c": st(s.n, ("batch", "kv_seq", "kv_lora")),
                         "kr": st(s.n, ("batch", "kv_seq", None))})
        elif s.kind == "dec":
            axes.append(dict(kv_entry(s.n),
                             xk=st(s.n, kv), xv=st(s.n, kv)))
        elif s.kind == "mamba":
            axes.append(mamba_ax(pre))
        elif s.kind == "zamba_group":
            axes.append({"mamba": mamba_ax(pre + (None,)),
                         "attn": kv_entry(s.n)})
        elif s.kind == "xlstm_group":
            axes.append({"m": {"C": pre + (None, "batch", "ssm_heads", None, None),
                               "n": pre + (None, "batch", "ssm_heads", None)},
                         "s": {k: pre + ("batch", "ssm_heads", None)
                               for k in ("h", "c", "n", "m")}})
    return axes


def _block_decode(kind, p, h, cache, pos, cfg, shared=None, rules=None):
    """Single-token decode for one block. h [B,1,D]."""
    p = _maybe_dequant(p)
    if kind in ("dense", "moe", "enc"):
        hn = L.apply_norm(p["ln1"], h, cfg.norm)
        a, cache = L.gqa_decode(p["attn"], hn, cfg, cache, pos)
        if cfg.parallel_block:
            h = h + a + L.apply_mlp(p["mlp"], hn, cfg, rules)
        else:
            h = h + a
            hn2 = L.apply_norm(p["ln2"], h, cfg.norm)
            if kind == "moe":
                m, _ = MOE.apply_moe(p["moe"], hn2, cfg)
            else:
                m = L.apply_mlp(p["mlp"], hn2, cfg, rules)
            h = h + m
    elif kind in ("mla_dense", "mla_moe"):
        hn = L.apply_norm(p["ln1"], h, cfg.norm)
        a, cc, ckr = L.mla_decode(p["attn"], hn, cfg, cache["c"], cache["kr"], pos)
        cache = {"c": cc, "kr": ckr}
        h = h + a
        hn2 = L.apply_norm(p["ln2"], h, cfg.norm)
        if kind == "mla_moe":
            m, _ = MOE.apply_moe(p["moe"], hn2, cfg)
        else:
            m = L.apply_mlp(p["mlp"], hn2, cfg, rules)
        h = h + m
    elif kind == "dec":
        hn = L.apply_norm(p["ln1"], h, cfg.norm)
        kv_in = {k: cache[k] for k in cache if not k.startswith("x")}
        a, kv_out = L.gqa_decode(p["attn"], hn, cfg, kv_in, pos)
        h = h + a
        hx = L.apply_norm(p["lnx"], h, cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", hx, p["xattn"]["wq"])
        o = L.decode_attention(q, cache["xk"], cache["xv"],
                               jnp.full_like(pos, cache["xk"].shape[1] - 1))
        h = h + jnp.einsum("bshk,hkd->bsd", o, p["xattn"]["wo"])
        hn2 = L.apply_norm(p["ln2"], h, cfg.norm)
        h = h + L.apply_mlp(p["mlp"], hn2, cfg, rules)
        cache = dict(cache, **kv_out)
    elif kind == "mamba":
        hn = L.apply_norm(p["ln1"], h, cfg.norm)
        y, cache = SSM.mamba2_decode(p["mamba"], hn, cfg, cache)
        h = h + y
    elif kind == "zamba_group":
        new_m = []
        for i in range(cfg.shared_every):
            pm = jax.tree.map(lambda t: t[i], p["mambas"])
            ci = jax.tree.map(lambda t: t[i], cache["mamba"])
            hn = L.apply_norm(pm["ln1"], h, cfg.norm)
            y, ci = SSM.mamba2_decode(pm["mamba"], hn, cfg, ci)
            h = h + y
            new_m.append(ci)
        hn = L.apply_norm(shared["ln1"], h, cfg.norm)
        a, attn_cache = L.gqa_decode(shared["attn"], hn, cfg,
                                     cache["attn"], pos)
        h = h + a
        hn = L.apply_norm(shared["ln2"], h, cfg.norm)
        h = h + L.apply_mlp(shared["mlp"], hn, cfg, rules)
        cache = {"mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_m),
                 "attn": attn_cache}
    elif kind == "xlstm_group":
        order = [0, 1, 2, None, 3, 4]
        new_m = []
        s_state = cache["s"]
        for idx in order:
            if idx is None:
                hn = L.apply_norm(p["s"]["ln1"], h, cfg.norm)
                y, s_state = XL.slstm_decode(p["s"]["cell"], hn, cfg, s_state)
            else:
                pm = jax.tree.map(lambda t: t[idx], p["m"])
                ci = jax.tree.map(lambda t: t[idx], cache["m"])
                hn = L.apply_norm(pm["ln1"], h, cfg.norm)
                y, ci = XL.mlstm_decode(pm["cell"], hn, cfg, ci)
                new_m.append(ci)
            h = h + y
        cache = {"m": jax.tree.map(lambda *xs: jnp.stack(xs), *new_m),
                 "s": s_state}
    else:
        raise ValueError(kind)
    return h, cache


def decode_step(params, cfg: ModelConfig, tokens, pos, caches, rules=None):
    """tokens [B], pos [B] -> (logits [B,V], new caches)."""
    params = {k: (_maybe_dequant(v) if k != "stages" else v)
              for k, v in params.items()}
    h = jnp.take(params["embed"], tokens[:, None], axis=0)
    if cfg.family == "audio":
        h = h + params["dec_pos"][pos][:, None].astype(h.dtype)
    stages = build_stages(cfg)
    new_caches = []
    for st, sp, cache in zip(stages, params["stages"], caches):
        if cfg.family == "audio" and st.kind == "enc":
            new_caches.append(cache)  # encoder is inactive during decode
            continue

        def body(hh, xs, _kind=st.kind):
            pl, cl = xs
            hh, cl = _block_decode(_kind, pl, hh, cl, pos, cfg,
                                   shared=params.get("shared"), rules=rules)
            return hh, cl
        if st.n == 1:
            h, nc = body(h, (sp, cache))
        else:
            h, nc = jax.lax.scan(body, h, (sp, cache))
        new_caches.append(nc)
    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (h[:, 0] @ head) * cfg.logit_scale
    if rules is not None:
        logits = constrain(logits, ("batch", "vocab"), rules)
    return logits, new_caches


def _pad_kv(kv, cache_len, window):
    """kv [..., S, H, hd] -> cache [..., W, H, hd] (ring layout for SWA)."""
    S = kv.shape[-3]
    if window and S >= window:
        tail = kv[..., S - window:, :, :]
        return jnp.roll(tail, S % window, axis=-3)
    W = min(cache_len, window) if window else cache_len
    pad = [(0, 0)] * kv.ndim
    pad[-3] = (0, W - S)
    return jnp.pad(kv, pad)


def assemble_caches(cfg: ModelConfig, kv_stacks, cache_len: int, seq_len: int):
    """Turn ``forward(collect_cache=True)`` outputs into decode caches."""
    W = cfg.sliding_window

    def kv_assemble(k, v):
        if cfg.kv_quant:
            from repro.models.layers import kv_quantize
            kq, ks = kv_quantize(k)
            vq, vs = kv_quantize(v)
            return {"k": _pad_kv(kq, cache_len, W),
                    "k_s": _pad_kv(ks, cache_len, W),
                    "v": _pad_kv(vq, cache_len, W),
                    "v_s": _pad_kv(vs, cache_len, W)}
        return {"k": _pad_kv(k, cache_len, W), "v": _pad_kv(v, cache_len, W)}

    caches = []
    for st, kvs in zip(build_stages(cfg), kv_stacks):
        if st.kind in ("dense", "moe", "enc"):
            caches.append(kv_assemble(kvs["k"], kvs["v"]))
        elif st.kind in ("mla_dense", "mla_moe"):
            caches.append({
                "c": _pad_kv(kvs["c"][..., None], cache_len, 0)[..., 0],
                "kr": _pad_kv(kvs["kr"][..., None], cache_len, 0)[..., 0]})
        elif st.kind == "dec":
            caches.append(dict(kv_assemble(kvs["k"], kvs["v"]),
                               xk=kvs["xk"], xv=kvs["xv"]))
        elif st.kind == "zamba_group":
            caches.append({"mamba": kvs["mamba"],
                           "attn": kv_assemble(kvs["attn"]["k"],
                                               kvs["attn"]["v"])})
        else:  # mamba / xlstm_group: states pass through unchanged
            caches.append(kvs)
    return caches


def prefill(params, cfg: ModelConfig, batch, cache_len: int, rules=None):
    """Full-sequence forward + populated decode caches.

    Returns (logits [B,S,V], caches).  This is what ``prefill_*`` dry-run
    cells lower and what the serving engine records (the paper's per-layer
    "recording" granularity corresponds to per-stage executables; we record
    at step granularity: prefill / decode)."""
    out = forward(params, cfg, batch, rules=rules, collect_cache=True)
    logits, _aux, kv_stacks = out
    S = batch["tokens"].shape[1]
    if cfg.family == "vlm" and "image_embeds" in batch:
        S += batch["image_embeds"].shape[1]   # image prefix lives in cache
    caches = assemble_caches(cfg, kv_stacks, max(cache_len, S), S)
    return logits, caches


__all__ = ["ModelConfig", "StageDef", "build_stages", "model_schema",
           "init_params", "abstract_params", "param_axes", "forward",
           "decode_step", "init_cache", "prefill", "assemble_caches"]
