"""Core layers: schemas (shape+logical-axes), norms, RoPE, attention, MLP.

Params are described by ``ParamSpec`` schemas so the same definition serves
three consumers: real init (tests/examples), abstract init (dry-run
ShapeDtypeStructs), and sharding resolution (logical axes -> PartitionSpec).

Attention is computed in query chunks (flash-style memory footprint in pure
JAX; the Pallas kernel in repro.kernels is a drop-in for real TPUs).  SWA
slices only the needed KV window per query chunk, so 500k-token sequences
never materialize quadratic scores.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import constrain


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    scale: float = 0.02          # init std; 0.0 -> zeros; -1.0 -> ones
    dtype: Optional[str] = None  # None -> model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def materialize(schema, key, default_dtype):
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, sp in zip(keys, leaves):
        dt = jnp.dtype(sp.dtype or default_dtype)
        if sp.scale == 0.0:
            out.append(jnp.zeros(sp.shape, dt))
        elif sp.scale == -1.0:
            out.append(jnp.ones(sp.shape, dt))
        else:
            out.append((jax.random.normal(k, sp.shape, jnp.float32) * sp.scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract(schema, default_dtype):
    return jax.tree.map(
        lambda sp: jax.ShapeDtypeStruct(sp.shape, jnp.dtype(sp.dtype or default_dtype)),
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))


def axes_tree(schema):
    return jax.tree.map(lambda sp: sp.axes, schema,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------- norms ----
def norm_schema(d, kind="rmsnorm"):
    s = {"scale": ParamSpec((d,), ("norm",), -1.0, "float32")}
    if kind == "layernorm":
        s["bias"] = ParamSpec((d,), ("norm",), 0.0, "float32")
    return s


def apply_norm(p, x, kind="rmsnorm", eps=1e-5):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        xf = xf - xf.mean(-1, keepdims=True)
    var = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y.astype(x.dtype)


# ----------------------------------------------------------------- rope ----
def rope(x, pos, theta):
    """x: [..., S, H, hd]; pos: broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freq          # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def _masked_softmax(scores, mask):
    scores = jnp.where(mask, scores, -1e30)
    m = jnp.max(scores, -1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    denom = jnp.sum(e, -1, keepdims=True)
    return e / jnp.maximum(denom, 1e-30)


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      chunk=1024, rules=None):
    """q [B,Sq,H,hd]; k,v [B,Sk,Hkv,hd]; positions: q at q_offset+i, k at j.

    Scans over query chunks; with SWA only the [start-W, end) KV slice is
    touched per chunk, keeping both memory and FLOPs sub-quadratic.
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    scale = hd ** -0.5
    if Hkv != H:  # GQA: repeat KV so the head dim shards cleanly over TP
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    if rules is not None:
        hax = ("batch", None, "heads", "head_dim")
        q, k, v = (constrain(t, hax, rules) for t in (q, k, v))
    chunk = min(chunk, Sq)
    pad = (-Sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = q.shape[1] // chunk
    qs = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    kv_span = min(Sk, (window + chunk) if window else Sk)

    def body(c, qc):
        q_start = c * chunk + q_offset
        if window:
            start = jnp.clip(q_start + chunk - kv_span, 0, max(Sk - kv_span, 0))
        else:
            start = 0
        kc = jax.lax.dynamic_slice_in_dim(k, start, kv_span, 1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, kv_span, 1)
        pq = q_start + jnp.arange(chunk)
        pk = start + jnp.arange(kv_span)
        mask = jnp.ones((chunk, kv_span), bool)
        if causal:
            mask &= pq[:, None] >= pk[None, :]
        if window:
            mask &= pq[:, None] - pk[None, :] < window
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        a = _masked_softmax(s, mask[None, None])
        o = jnp.einsum("bhqk,bkhd->bqhd", a.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
        return c + 1, o.astype(q.dtype)

    _, outs = jax.lax.scan(body, jnp.int32(0), qs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, H, hd_v)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, pos, *, window=0,
                     k_scale=None, v_scale=None):
    """q [B,1,H,hd]; caches [B,W,Hkv,hd]; pos [B] current absolute position.

    Ring cache for SWA (slot = p % W); dense cache otherwise (slot = p).
    GQA handled by grouping q as [B,Hkv,G,hd] against the Hkv-cache — the
    cache stays SP-sharded on W (kv_seq), so the group reshape is benign.
    """
    B, _, H, hd = q.shape
    W, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = hd ** -0.5
    slots = jnp.arange(W)
    if window:
        slot_pos = pos[:, None] - ((pos[:, None] - slots[None]) % W)
    else:
        slot_pos = jnp.broadcast_to(slots[None], (B, W))
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window:
        valid &= pos[:, None] - slot_pos < window
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if k_scale is not None:  # int8 cache: apply per-(token,head) scales
        s = s * k_scale[..., 0].transpose(0, 2, 1)[:, :, None, :]
    a = _masked_softmax(s, valid[:, None, None])
    if v_scale is not None:
        a = a * v_scale[..., 0].transpose(0, 2, 1)[:, :, None, :]
        o = jnp.einsum("bhgk,bkhd->bhgd", a, v_cache.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    else:
        o = jnp.einsum("bhgk,bkhd->bhgd", a.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def gqa_schema(cfg):
    D, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd()
    s = {
        "wq": ParamSpec((D, H, hd), ("fsdp", "heads", "head_dim"), D ** -0.5),
        "wk": ParamSpec((D, Hkv, hd), ("fsdp", "kv_heads", "head_dim"), D ** -0.5),
        "wv": ParamSpec((D, Hkv, hd), ("fsdp", "kv_heads", "head_dim"), D ** -0.5),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "fsdp"), (H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), 0.0)
        s["bk"] = ParamSpec((Hkv, hd), ("kv_heads", "head_dim"), 0.0)
        s["bv"] = ParamSpec((Hkv, hd), ("kv_heads", "head_dim"), 0.0)
    return s


def gqa_qkv(p, x, cfg, pos):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.rope_theta:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def gqa_attention(p, x, cfg, *, rules=None, cross_kv=None, causal=True):
    """Full-sequence (train / prefill) GQA or cross attention."""
    B, S, _ = x.shape
    pos = jnp.arange(S)[None]
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if "bq" in p:
            q = q + p["bq"]
        k, v = cross_kv
        causal = False
    else:
        q, k, v = gqa_qkv(p, x, cfg, pos)
    o = chunked_attention(q, k, v, causal=causal,
                          window=cfg.sliding_window, rules=rules)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (k, v)


def kv_quantize(t):
    """t [..., Hkv, hd] -> (int8, f32 scale [..., Hkv, 1])."""
    f = t.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(f), axis=-1, keepdims=True), 1e-6) / 127.0
    return jnp.clip(jnp.round(f / s), -127, 127).astype(jnp.int8), s


def gqa_decode(p, x, cfg, cache, pos):
    """x [B,1,D]; cache dict {'k','v'[, 'k_s','v_s']} -> (out, new cache)."""
    q, k, v = gqa_qkv(p, x, cfg, pos[:, None])
    W = cache["k"].shape[1]
    slot = (pos % W) if cfg.sliding_window else pos
    bidx = jnp.arange(x.shape[0])
    if cfg.kv_quant:
        kq, ks = kv_quantize(k[:, 0])
        vq, vs = kv_quantize(v[:, 0])
        cache = {"k": cache["k"].at[bidx, slot].set(kq),
                 "k_s": cache["k_s"].at[bidx, slot].set(ks),
                 "v": cache["v"].at[bidx, slot].set(vq),
                 "v_s": cache["v_s"].at[bidx, slot].set(vs)}
        o = decode_attention(q, cache["k"], cache["v"], pos,
                             window=cfg.sliding_window,
                             k_scale=cache["k_s"], v_scale=cache["v_s"])
    else:
        cache = {"k": cache["k"].at[bidx, slot].set(k[:, 0]),
                 "v": cache["v"].at[bidx, slot].set(v[:, 0])}
        o = decode_attention(q, cache["k"], cache["v"], pos,
                             window=cfg.sliding_window)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), cache


# ------------------------------------------------------------------ MLA ----
def mla_schema(cfg):
    D, H = cfg.d_model, cfg.num_heads
    m = cfg.mla
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": ParamSpec((D, H, qk), ("fsdp", "heads", "head_dim"), D ** -0.5),
        "w_dkv": ParamSpec((D, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("fsdp", "kv_lora"), D ** -0.5),
        "kv_norm": norm_schema(m.kv_lora_rank),
        "w_uk": ParamSpec((m.kv_lora_rank, H, m.qk_nope_head_dim),
                          ("kv_lora", "heads", "head_dim"), m.kv_lora_rank ** -0.5),
        "w_uv": ParamSpec((m.kv_lora_rank, H, m.v_head_dim),
                          ("kv_lora", "heads", "head_dim"), m.kv_lora_rank ** -0.5),
        "wo": ParamSpec((H, m.v_head_dim, D), ("heads", "head_dim", "fsdp"),
                        (H * m.v_head_dim) ** -0.5),
    }


def _mla_latent(p, x, cfg, pos):
    m = cfg.mla
    ckr = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv, k_rope = ckr[..., :m.kv_lora_rank], ckr[..., m.kv_lora_rank:]
    c_kv = apply_norm(p["kv_norm"], c_kv)
    k_rope = rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(p, x, cfg, *, rules=None):
    """Prefill/train: decompress latent to per-head K/V, chunked attention."""
    B, S, _ = x.shape
    m = cfg.mla
    pos = jnp.arange(S)[None]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, pos, cfg.rope_theta)
    c_kv, k_rope = _mla_latent(p, x, cfg, pos)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    H = cfg.num_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, m.qk_rope_head_dim))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    o = chunked_attention(qf, k, v, causal=True, rules=rules)
    # pad v-dim back: o has head_dim qk? no — v head dim = m.v_head_dim
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (c_kv, k_rope)


def mla_decode(p, x, cfg, cache_c, cache_kr, pos):
    """Absorbed-matrices decode: scores/combine in the 512-d latent space."""
    m = cfg.mla
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, pos[:, None], cfg.rope_theta)
    c_kv, k_rope = _mla_latent(p, x, cfg, pos[:, None])
    bidx = jnp.arange(B)
    cache_c = cache_c.at[bidx, pos].set(c_kv[:, 0])
    cache_kr = cache_kr.at[bidx, pos].set(k_rope[:, 0])
    # absorb W_uk into q:   q_lat [B,H,R]
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["w_uk"])
    s = jnp.einsum("bhr,bsr->bhs", q_lat, cache_c,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], cache_kr,
                       preferred_element_type=jnp.float32)
    s = s * ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    valid = jnp.arange(cache_c.shape[1])[None] <= pos[:, None]
    a = _masked_softmax(s, valid[:, None])
    ctx = jnp.einsum("bhs,bsr->bhr", a.astype(cache_c.dtype), cache_c,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    o = jnp.einsum("bhr,rhk->bhk", ctx, p["w_uv"])
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    return out, cache_c, cache_kr


# ------------------------------------------------------------------ MLP ----
def mlp_schema(cfg, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    s = {"w2": ParamSpec((F, D), ("ffn", "fsdp"), F ** -0.5)}
    if cfg.act == "silu":
        s["w1"] = ParamSpec((D, F), ("fsdp", "ffn"), D ** -0.5)
        s["w3"] = ParamSpec((D, F), ("fsdp", "ffn"), D ** -0.5)
    else:
        s["w1"] = ParamSpec((D, F), ("fsdp", "ffn"), D ** -0.5)
        if cfg.mlp_bias:
            s["b1"] = ParamSpec((F,), ("ffn",), 0.0)
            s["b2"] = ParamSpec((D,), ("norm",), 0.0)
    return s


def apply_mlp(p, x, cfg, rules=None):
    cst = (lambda t: constrain(t, ("batch", None, "ffn"), rules)) \
        if (rules is not None and x.ndim == 3) else (lambda t: t)
    if "w3" in p:
        h = cst(jax.nn.silu(x @ p["w1"])) * cst(x @ p["w3"])
    else:
        h = x @ p["w1"]
        if "b1" in p:
            h = h + p["b1"]
        h = cst(jax.nn.gelu(h))
    y = h @ p["w2"]
    if "b2" in p:
        y = y + p["b2"]
    return y
