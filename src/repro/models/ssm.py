"""Mamba2 (SSD) blocks — chunked parallel train/prefill + recurrent decode.

The inter-chunk recurrence uses ``jax.lax.associative_scan`` (log-depth,
fully unrolled) rather than ``lax.scan`` so the HLO roofline analyzer sees
its true cost without trip-count correction.  Projections are separate
weight matrices (z/x/B/C/dt) so TP sharding never slices a sharded dim.
The Pallas kernel (repro/kernels/mamba_scan.py) mirrors the intra-chunk
math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, apply_norm, norm_schema
from repro.sharding import constrain


def pick_chunk(S: int, chunk: int) -> int:
    """Largest divisor of S that is <= chunk (SSD chunk must divide S)."""
    q = min(chunk, S)
    while S % q:
        q -= 1
    return q


def mamba2_dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = s.num_heads or d_in // s.head_dim
    return d_in, nh, s.head_dim, s.state_dim


def mamba2_schema(cfg):
    D = cfg.d_model
    d_in, nh, P, N = mamba2_dims(cfg)
    K = cfg.ssm.conv_width
    return {
        "w_z": ParamSpec((D, d_in), ("fsdp", "ssm_inner"), D ** -0.5),
        "w_x": ParamSpec((D, d_in), ("fsdp", "ssm_inner"), D ** -0.5),
        "w_B": ParamSpec((D, N), ("fsdp", None), D ** -0.5),
        "w_C": ParamSpec((D, N), ("fsdp", None), D ** -0.5),
        "w_dt": ParamSpec((D, nh), ("fsdp", "ssm_heads"), D ** -0.5),
        "conv_x": ParamSpec((K, d_in), ("conv", "ssm_inner"), 0.1),
        "conv_b": ParamSpec((K, 2 * N), ("conv", None), 0.1),
        "bias_x": ParamSpec((d_in,), ("ssm_inner",), 0.0),
        "bias_bc": ParamSpec((2 * N,), (None,), 0.0),
        "A_log": ParamSpec((nh,), ("ssm_heads",), 0.0, "float32"),
        "D_skip": ParamSpec((nh,), ("ssm_heads",), -1.0, "float32"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), 0.02, "float32"),
        "norm": norm_schema(d_in),
        "out_proj": ParamSpec((d_in, D), ("ssm_inner", "fsdp"), d_in ** -0.5),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along S.  x [B,S,C]; w [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _proj_all(p, x, cfg, rules=None):
    """-> z [..,d_in], xs raw [..,d_in], BC raw [..,2N], dt [..,nh]."""
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    BC = jnp.concatenate([x @ p["w_B"], x @ p["w_C"]], -1)
    dt = x @ p["w_dt"]
    if rules is not None and x.ndim == 3:
        z = constrain(z, ("batch", None, "ssm_inner"), rules)
        xs = constrain(xs, ("batch", None, "ssm_inner"), rules)
    return z, xs, BC, dt


def mamba2_forward(p, x, cfg, rules=None):
    """x [B,S,D] -> (y [B,S,D], final state) via chunked SSD."""
    B, S, D = x.shape
    d_in, nh, P, N = mamba2_dims(cfg)
    Q = pick_chunk(S, cfg.ssm.chunk)
    nc = S // Q

    z, xs_raw, BC_raw, dt = _proj_all(p, x, cfg, rules)
    conv_tail = {"x": xs_raw[:, -(cfg.ssm.conv_width - 1):],
                 "bc": BC_raw[:, -(cfg.ssm.conv_width - 1):]}
    xs = _causal_conv(xs_raw, p["conv_x"], p["bias_x"]).reshape(B, S, nh, P)
    BC = _causal_conv(BC_raw, p["conv_b"], p["bias_bc"])
    Bm, Cm = BC[..., :N], BC[..., N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,nh]
    A = -jnp.exp(p["A_log"])                            # [nh]
    da = dt * A                                         # log-decay [B,S,nh]

    # chunk views
    c = lambda t: t.reshape(B, nc, Q, *t.shape[2:])
    xs_c, B_c, C_c, da_c, dt_c = c(xs), c(Bm), c(Cm), c(da), c(dt)
    cum = jnp.cumsum(da_c, axis=2)                      # [B,nc,Q,nh]

    xbar = (xs_c * dt_c[..., None]).astype(jnp.float32)
    # ---- intra-chunk (diagonal) ----
    scores = jnp.einsum("bcin,bcjn->bcij", C_c.astype(jnp.float32),
                        B_c.astype(jnp.float32))        # [B,nc,Q,Q]
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :])  # [B,nc,Q,Q,nh]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    lmat = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, lmat, xbar)

    # ---- chunk states ----
    rem = jnp.exp(cum[:, :, -1:, :] - cum)              # decay to chunk end
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", B_c.astype(jnp.float32),
                        rem, xbar)                       # [B,nc,nh,P,N]
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # [B,nc,nh]

    # ---- inter-chunk associative scan:  H_c = H_{c-1} * d_c + S_c ----
    def comb(a, b):
        d1, s1 = a
        d2, s2 = b
        return d1 * d2, s1 * d2[..., None, None] + s2
    dsc, ssc = jax.lax.associative_scan(comb, (chunk_decay, states), axis=1)
    # H entering chunk c is the scanned state of chunk c-1
    h_prev = jnp.concatenate(
        [jnp.zeros_like(ssc[:, :1]), ssc[:, :-1]], axis=1)  # [B,nc,nh,P,N]

    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", C_c.astype(jnp.float32),
                       jnp.exp(cum), h_prev)
    y = (y_diag + y_off).reshape(B, S, nh, P)
    y = y + p["D_skip"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = apply_norm(p["norm"], y)
    return y @ p["out_proj"], {"ssm": ssc[:, -1], "conv": conv_tail}


def mamba2_init_state(cfg, batch, dtype):
    d_in, nh, P, N = mamba2_dims(cfg)
    K = cfg.ssm.conv_width
    return {
        "ssm": jnp.zeros((batch, nh, P, N), jnp.float32),
        "conv": {"x": jnp.zeros((batch, K - 1, d_in), dtype),
                 "bc": jnp.zeros((batch, K - 1, 2 * N), dtype)},
    }


def mamba2_decode(p, x, cfg, state):
    """x [B,1,D]; recurrent single-token update."""
    B = x.shape[0]
    d_in, nh, P, N = mamba2_dims(cfg)
    z, xs_raw, BC_raw, dt = _proj_all(p, x[:, 0], cfg)
    win_x = jnp.concatenate([state["conv"]["x"], xs_raw[:, None]], 1)
    xs = jax.nn.silu((win_x * p["conv_x"][None]).sum(1) + p["bias_x"])
    win_bc = jnp.concatenate([state["conv"]["bc"], BC_raw[:, None]], 1)
    BC = jax.nn.silu((win_bc * p["conv_b"][None]).sum(1) + p["bias_bc"])
    new_conv = {"x": win_x[:, 1:], "bc": win_bc[:, 1:]}
    xs = xs.reshape(B, nh, P)
    Bm, Cm = BC[..., :N], BC[..., N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,nh]
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))                        # [B,nh]
    xbar = (xs * dt[..., None]).astype(jnp.float32)
    h = state["ssm"] * a[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", Bm.astype(jnp.float32), xbar)
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + p["D_skip"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, d_in).astype(x.dtype) * jax.nn.silu(z)
    y = apply_norm(p["norm"], y)
    return (y @ p["out_proj"])[:, None], {"ssm": h, "conv": new_conv}
