"""Unified model configuration covering all assigned architecture families.

A single ``ModelConfig`` describes dense/MoE transformers (GQA/SWA/MLA),
encoder-decoder (whisper), SSM (xLSTM), VLM backbones (phi-3-vision) and
hybrid SSM+attention (zamba2).  Configs are plain dataclasses so they can be
hashed into recording fingerprints (repro.core.attest).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0     # always-on shared experts (deepseek)
    top_k: int = 2
    expert_d_ff: int = 0            # per-expert hidden size
    capacity_factor: float = 1.25   # dispatch capacity (train); serve uses exact top-k
    group_size: int = 1024          # dispatch group (memory ~ T*g*topk*cf)
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64             # mamba2 N (per-head SSM state)
    num_heads: int = 0              # mamba2 heads (0 -> derived d_inner//head_dim)
    head_dim: int = 64              # mamba2 P
    expand: int = 2                 # d_inner = expand * d_model
    chunk: int = 256                # SSD chunk length
    conv_width: int = 4             # depthwise conv width (stubbed as pointwise mix)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_at: Tuple[int, ...] = ()  # layer indices using sLSTM blocks
    proj_factor_m: float = 2.0      # mLSTM up-projection factor
    proj_factor_s: float = 1.3334   # sLSTM ffn factor
    chunk: int = 256                # mLSTM chunkwise-parallel chunk length


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    num_encoder_layers: int = 0
    encoder_seq: int = 1500         # whisper: fixed #frames after conv frontend (stub)


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    num_image_tokens: int = 576     # CLIP patch embeds prepended (stub frontend)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | audio | ssm | vlm | hybrid
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 0               # 0 -> d_model // num_heads
    d_ff: int = 256
    vocab_size: int = 512
    max_seq: int = 8192

    # attention flavor
    attention: str = "gqa"          # gqa | mla | none (ssm)
    sliding_window: int = 0         # 0 -> full attention; >0 -> SWA window
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    mlp_bias: bool = False
    parallel_block: bool = False    # command-r: attn & ffn in parallel off one norm
    logit_scale: float = 1.0
    tie_embeddings: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "silu"               # silu (gated) | gelu (whisper: non-gated)

    # sub-configs (None if unused)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    mla: Optional[MLAConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None

    # hybrid (zamba2): shared attention block applied every `shared_every` ssm layers
    shared_every: int = 0
    dense_first_layer_d_ff: int = 0  # deepseek: layer 0 is dense with this d_ff

    dtype: str = "bfloat16"
    kv_quant: bool = False          # int8 KV cache (per-token/head scales)

    # ---- derived ----
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def is_subquadratic(self) -> bool:
        """True if long-context (500k) decode is feasible (not full attention)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decode path

    def fingerprint(self) -> str:
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # Analytic parameter count (for 6ND MODEL_FLOPS and checkpoint planning).
    def param_count(self, active_only: bool = False) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, Hkv, hd = self.num_heads, self.num_kv_heads, self.hd()
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D

        def attn_params() -> int:
            if self.attention == "mla":
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = D * H * qk                                     # W_q
                p += D * (m.kv_lora_rank + m.qk_rope_head_dim)     # W_dkv (+rope k)
                p += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                p += H * m.v_head_dim * D                          # W_o
                return p
            return D * H * hd + 2 * D * Hkv * hd + H * hd * D

        def mlp_params(ff: int) -> int:
            mult = 3 if self.act == "silu" else 2  # gated vs plain
            return mult * D * ff

        def moe_params(active: bool) -> int:
            m = self.moe
            e = (m.top_k if active else m.num_experts) + m.num_shared_experts
            return e * mlp_params(m.expert_d_ff) // 1 + D * m.num_experts  # + router

        if self.family == "ssm" and self.xlstm is not None:
            x = self.xlstm
            d_in_m = int(D * x.proj_factor_m)
            n_s = len(x.slstm_at)
            n_m = L - n_s
            # mLSTM: up (z & x paths) + full qkv proj on inner + gates + down
            per_m = 2 * D * d_in_m + 3 * d_in_m * d_in_m + 2 * D * H + \
                d_in_m + d_in_m * D
            d_h = D // max(H, 1)
            per_s = 4 * D * D + 4 * H * d_h * d_h + \
                3 * int(D * x.proj_factor_s) * D + D
            total += n_m * per_m + n_s * per_s
            total += D  # final norm
            return total

        if self.family == "hybrid" and self.ssm is not None:
            s = self.ssm
            d_in = s.expand * D
            nh = s.num_heads or d_in // s.head_dim
            per_ssm = D * (2 * d_in + 2 * nh * s.state_dim + nh) + d_in * D + d_in
            n_shared = 1 if self.shared_every else 0
            shared = attn_params() + mlp_params(F) if n_shared else 0
            total += self.num_layers * per_ssm + shared
            total += D
            return total

        per_layer_dense = attn_params() + mlp_params(F)
        if self.family == "moe" and self.moe is not None:
            n_moe = L - (1 if self.dense_first_layer_d_ff else 0)
            moe_part = attn_params() + moe_params(active_only)
            total += n_moe * moe_part
            if self.dense_first_layer_d_ff:
                total += attn_params() + mlp_params(self.dense_first_layer_d_ff)
        elif self.family == "audio" and self.encdec is not None:
            enc = self.encdec.num_encoder_layers * (attn_params() + mlp_params(F))
            dec = L * (2 * attn_params() + mlp_params(F))  # self + cross attn
            total += enc + dec
        else:
            total += L * per_layer_dense
        total += D  # final norm
        return total
