"""Top-k routed Mixture-of-Experts with capacity-based einsum dispatch.

MaxText-style dropping MoE: tokens are split into groups of ``group_size``;
per group, each expert takes at most C = group*top_k/E*capacity tokens
(one-hot dispatch/combine einsums — TPU-friendly, no scatters).  The
dispatch-einsum overhead scales with C, so ``group_size`` is a tunable knob
(hillclimbed in EXPERIMENTS.md §Perf: small groups for many-small-expert
models like deepseek, large for mixtral).

Sharding: expert weights are [E, D, F].  Two modes (cfg via logical axes):
  * "ffn"   (mixtral, E=8  < TP): F -> tp, D -> dp   (TP inside each expert)
  * "expert"(deepseek, E=64 >= TP): E -> tp (EP), D -> dp
Router is tiny and replicated.  Shared experts (deepseek) are plain MLPs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec
from repro.sharding import constrain


def moe_schema(cfg, shard_mode: str):
    D = cfg.d_model
    m = cfg.moe
    E, F = m.num_experts, m.expert_d_ff
    e_ax = "experts" if shard_mode == "expert" else None
    f_ax = None if shard_mode == "expert" else "expert_ffn"
    s = {
        "router": ParamSpec((D, E), ("norm", "experts"), D ** -0.5, "float32"),
        "w1": ParamSpec((E, D, F), (e_ax, "expert_embed", f_ax), D ** -0.5),
        "w3": ParamSpec((E, D, F), (e_ax, "expert_embed", f_ax), D ** -0.5),
        "w2": ParamSpec((E, F, D), (e_ax, f_ax, "expert_embed"), F ** -0.5),
    }
    if m.num_shared_experts:
        Fs = F * m.num_shared_experts
        s["shared_w1"] = ParamSpec((D, Fs), ("fsdp", "ffn"), D ** -0.5)
        s["shared_w3"] = ParamSpec((D, Fs), ("fsdp", "ffn"), D ** -0.5)
        s["shared_w2"] = ParamSpec((Fs, D), ("ffn", "fsdp"), Fs ** -0.5)
    return s


def _capacity(group: int, top_k: int, E: int, factor: float) -> int:
    c = int(group * top_k / E * factor)
    return max(top_k, min(group, (c + 3) // 4 * 4))


def apply_moe(p, x, cfg, *, rules=None, group_size: int = 0,
              deterministic_capacity=None):
    """x [B,S,D] -> [B,S,D]."""
    B, S, D = x.shape
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    T = B * S
    g = group_size or m.group_size or min(T, 4096)
    g = min(g, T)
    n_groups = T // g
    assert n_groups * g == T, f"tokens {T} not divisible by group {g}"
    xt = x.reshape(n_groups, g, D)

    logits = jnp.einsum("ngd,de->nge", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                     # [n,g,E]
    top_g, top_i = jax.lax.top_k(gates, K)                      # [n,g,K]
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    C = deterministic_capacity or _capacity(g, K, E, m.capacity_factor)
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)        # [n,g,K,E]
    pos_in_e = (jnp.cumsum(onehot.reshape(n_groups, g * K, E), 1)
                .reshape(n_groups, g, K, E) - onehot)           # [n,g,K,E]
    keep = (pos_in_e < C) * onehot
    slot = jax.nn.one_hot(pos_in_e.astype(jnp.int32), C, dtype=jnp.float32)
    dispatch = jnp.einsum("ngke,ngkec->ngec", keep, slot)       # [n,g,E,C]
    combine = jnp.einsum("ngke,ngk,ngkec->ngec", keep, top_g, slot)

    # expert compute; explicit constraints pin EP ('expert' mode: tokens
    # all-to-all to their experts) or per-expert TP ('ffn' mode: token
    # groups STAY dp-sharded — an unsharded n dim would all-gather the
    # 32 GB dispatch tensors, measured as mixtral's 260 s/step bottleneck,
    # EXPERIMENTS.md §Perf iter 5).
    expert_mode = p["w1"].shape[0] >= 16
    cst = lambda t, ax: constrain(t, ax, rules) if rules is not None else t
    if expert_mode:   # EP: shard experts, replicate groups (a2a dispatch)
        xe_ax, h_ax = (None, "experts", None, None), \
            (None, "experts", None, None)
    else:             # per-expert TP: shard groups (dp) + expert ffn (tp)
        xe_ax, h_ax = ("batch", None, None, None), \
            ("batch", None, "expert_ffn", None)
    xe = jnp.einsum("ngec,ngd->nedc", dispatch.astype(x.dtype), xt)  # [n,E,D,C]
    xe = cst(xe, xe_ax)
    h = jnp.einsum("nedc,edf->nefc", xe, p["w1"])
    h = jax.nn.silu(h) * jnp.einsum("nedc,edf->nefc", xe, p["w3"])
    h = cst(h, h_ax)
    ye = jnp.einsum("nefc,efd->nedc", h, p["w2"])                # [n,E,D,C]
    ye = cst(ye, xe_ax)
    y = jnp.einsum("nedc,ngec->ngd", ye, combine.astype(x.dtype))

    if "shared_w1" in p:
        hs = jax.nn.silu(xt @ p["shared_w1"]) * (xt @ p["shared_w3"])
        hs = cst(hs, (None, None, "ffn"))
        y = y + hs @ p["shared_w2"]

    aux = _load_balance_loss(gates, top_i, E)
    return y.reshape(B, S, D), aux


def _load_balance_loss(gates, top_i, E):
    """Switch-style auxiliary load-balancing loss (mean over groups)."""
    me = jnp.mean(gates, axis=1)                                 # [n,E]
    ce = jnp.mean(jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32), axis=1)
    return E * jnp.mean(jnp.sum(me * ce, -1))
