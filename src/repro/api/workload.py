"""Workload — one (arch, shapes, mesh) driven through the full lifecycle.

A ``Workload`` derives the canonical registry identity ONCE
(``registry.key_for`` over ``static_meta_for`` + config + mesh
fingerprints) and exposes every lifecycle stage as a method: ``compile``
/ ``record`` (cloud role), ``publish`` / ``fetch`` (registry), and
``channel`` / ``engine`` (serving — live-jit, flat recordings, or
verified registry replay).  The step-building and static-meta helpers
that used to be copied between the record CLI, the serve CLI, and the
benchmarks live here, as module functions, and the CLIs re-export them.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.attest import fingerprint
from repro.core.channel import LiveChannel, NetemBilledChannel, ReplayChannel
from repro.core.recorder import (compile_artifact, mesh_descriptor, record,
                                 topology_fingerprint)
from repro.core.recording import Recording
from repro.core.replay import Replayer
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.registry import key_arch, key_for
from repro.serving.engine import Engine, cache_batch_axes_for
from repro.sharding import rules_for
from repro.training import steps as ST

KINDS = ("prefill", "decode")


def static_meta_for(kind: str, *, cache_len: int, block_k: int, batch: int,
                    seq: int, eos_id: int = 2) -> dict:
    """The shape/static description that parameterizes ``build_step`` —
    also the ``shapes`` component of the registry key, so record and
    serve derive identical keys from identical arguments.  ``seq`` only
    shapes prefill (decode steps one token per slot per iteration), so it
    is excluded from decode identity: a decode recording serves any
    prompt length.  ``eos_id`` is baked into the fused decode executable,
    so a NON-default value enters decode identity; the default stays out
    of the dict so existing published keys do not drift."""
    static = {"kind": kind, "cache_len": cache_len, "block_k": block_k,
              "batch": batch}
    if kind == "prefill":
        static["seq"] = seq
    elif eos_id != 2:
        static["eos_id"] = eos_id
    return static


def build_step(cfg, kind: str, rules, *, cache_len: int, block_k: int = 8,
               batch: int = 1, seq: int = 32, eos_id: int = 2):
    """Step function + abstract arg specs + donation map for one kind."""
    params = M.abstract_params(cfg)
    if kind == "prefill":
        fn = ST.make_prefill_step(cfg, rules, cache_len=cache_len)
        batch_spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        return fn, (params, batch_spec), ()
    if kind == "decode":
        fn = ST.make_fused_decode_step(cfg, rules, k=block_k, eos_id=eos_id)
        caches = jax.eval_shape(lambda: M.init_cache(cfg, batch, cache_len))
        toks = jax.ShapeDtypeStruct((batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
        return fn, (params, toks, pos, caches), (3,)
    raise ValueError(kind)


def recording_name(arch: str, kind: str, extra: str = "") -> str:
    """Flat on-disk filename for a recording (identity normalization is
    shared with the registry via ``key_arch``)."""
    return f"{key_arch(arch)}_{kind}{('_' + extra) if extra else ''}.codyrec"


def stream_kwargs(cfg, *, n_slots: int, cache_len: int, block_k: int,
                  eos_id: int, speculate: bool = True,
                  pipeline_depth: int = 4) -> dict:
    """Per-stream policy for ``Scheduler.add_stream`` derived from the
    model family: recurrent state is not position-indexed, so dropped
    pipeline tails cannot be re-executed against an already-advanced
    state — the engine's metastate-only rollback is unsound there and
    speculation is forced off."""
    if cfg.family in ("ssm", "hybrid"):
        speculate = False
    return dict(n_slots=n_slots, cache_len=cache_len, block_k=block_k,
                eos_id=eos_id,
                init_caches_fn=lambda: M.init_cache(cfg, n_slots, cache_len),
                cache_batch_axes=cache_batch_axes_for(cfg),
                speculate=speculate, pipeline_depth=pipeline_depth)


def format_session_report(rep: dict) -> str:
    """One-line summary of a RecordingSession report."""
    mb = (rep["bytes_sent"] + rep["bytes_received"]) / 1e6
    passes = "+".join(rep["passes"]) or "naive"
    return (f"session[{rep['net']}|{passes}]: "
            f"{rep['virtual_time_s']:.2f}s virtual, "
            f"{rep['blocking_round_trips']} blocking / "
            f"{rep['async_round_trips']} async RTs, {mb:.2f} MB, "
            f"{rep['jobs']} jobs")


class Workload:
    """One workload's lifecycle handle.  Built by ``Workspace.workload``;
    holds the model config, the mesh/sharding rules, and the shape tuple
    (``cache_len``, ``block_k``, ``batch`` = decode batch = serving
    slots, ``prefill_batch``, ``seq`` = prefill prompt length) that —
    together with the config and mesh fingerprints — IS the recording
    identity."""

    def __init__(self, workspace, cfg, *, cache_len: int = 128,
                 block_k: int = 8, batch: int = 4, prefill_batch: int = 1,
                 seq: int = 32, eos_id: int = 2, mesh=None):
        self.ws = workspace
        self.cfg = cfg
        self.cache_len = cache_len
        self.block_k = block_k
        self.batch = batch
        self.prefill_batch = prefill_batch
        self.seq = seq
        self.eos_id = eos_id
        self.mesh = mesh if mesh is not None else make_host_mesh(model=1)
        self.rules = rules_for("serve", self.mesh.axis_names)
        self.mesh_fp = fingerprint(mesh_descriptor(self.mesh))
        self.config_fp = cfg.fingerprint()
        # the canonical identity, derived once per kind and never re-derived
        self._keys = {k: key_for(cfg.name, k,
                                 {**self.static_meta(k),
                                  "config_fp": self.config_fp},
                                 self.mesh_fp) for k in KINDS}
        self.sessions = []        # (kind, session report) per record()
        self.replays = []         # (kind, executor report) per replay()
        self.replayers = []       # every Replayer built for this workload
        self._live: Optional[LiveChannel] = None
        self._params = {}         # seed -> initialized params

    # ------------------------------------------------------------ identity --
    def static_meta(self, kind: str) -> dict:
        batch = self.prefill_batch if kind == "prefill" else self.batch
        return static_meta_for(kind, cache_len=self.cache_len,
                               block_k=self.block_k, batch=batch,
                               seq=self.seq, eos_id=self.eos_id)

    def key(self, kind: str) -> str:
        """The registry key this workload records under, publishes under,
        fetches by, and caches replay executables under."""
        return self._keys[kind]

    def step(self, kind: str):
        static = self.static_meta(kind)
        return build_step(self.cfg, kind, self.rules,
                          cache_len=self.cache_len, block_k=self.block_k,
                          batch=static["batch"], seq=self.seq,
                          eos_id=self.eos_id)

    def params(self, seed: int = 0):
        """Initialized model params, memoized per seed (so solo engines
        and scheduler streams built from one workload share arrays)."""
        if seed not in self._params:
            self._params[seed] = M.init_params(self.cfg,
                                               jax.random.PRNGKey(seed))
        return self._params[seed]

    # -------------------------------------------------------------- record --
    def compile(self, kind: str = "prefill") -> Recording:
        """Cloud dryrun only: lower + compile + serialize, no session
        protocol.  Use with ``record(artifact=...)`` to amortize ONE
        compile across several session variants (serialized executables
        are not byte-deterministic across recompiles)."""
        fn, specs, donate = self.step(kind)
        return compile_artifact(self.key(kind), fn, specs, mesh=self.mesh,
                                donate_argnums=donate,
                                config_fingerprint=self.config_fp,
                                static_meta=self.static_meta(kind))

    def record(self, kind: str = "prefill", *, passes=None,
               artifact: Optional[Recording] = None,
               jobs: Optional[int] = None) -> Recording:
        """The paper's record phase: a distributed ``RecordingSession``
        (device proxy + cloud dryrun) over the workspace's link profile,
        with the optimization passes stacked in canonical order.  Returns
        the Recording with session accounting annotated into its manifest
        (``record_virtual_s`` / ``record_session``); the session report is
        also appended to ``self.sessions`` for ``report()``."""
        session = self.ws.session(passes=passes, jobs=jobs)
        if artifact is not None:
            # the artifact knows what it is — label the session by ITS
            # kind, not the (defaulted) argument
            kind = artifact.manifest.get("static", {}).get("kind", kind)
            rec = session.finalize(Recording(dict(artifact.manifest),
                                             artifact.payload,
                                             artifact.trees))
        else:
            fn, specs, donate = self.step(kind)
            rec = record(self.key(kind), fn, specs, mesh=self.mesh,
                         donate_argnums=donate,
                         config_fingerprint=self.config_fp,
                         static_meta=self.static_meta(kind), session=session)
        self.sessions.append((kind, session.report()))
        return rec

    def variants(self, *, seqs=None, kinds=KINDS):
        """Campaign work-list for this workload's shape variants:
        ``(Workload, kind)`` items covering every prefill ``seq`` bucket
        in ``seqs`` (sibling workloads sharing every other shape) plus
        the seq-independent kinds — feed to ``Workspace.campaign``.
        ``seqs=None`` keeps just this workload's own seq."""
        items = []
        for kind in kinds:
            if kind != "prefill":
                items.append((self, kind))
                continue
            for s in (seqs if seqs is not None else [self.seq]):
                wl = self if s == self.seq else self.ws.workload(
                    self.cfg, cache_len=self.cache_len,
                    block_k=self.block_k, batch=self.batch,
                    prefill_batch=self.prefill_batch, seq=s,
                    eos_id=self.eos_id, mesh=self.mesh)
                items.append((wl, "prefill"))
        return items

    # -------------------------------------------------------------- replay --
    def replay(self, kind: str = "prefill", *, passes=None,
               artifact: Optional[Recording] = None,
               jobs: Optional[int] = None) -> dict:
        """Replay-side interaction-plan execution: compact the recording's
        plan with the replay passes (``None`` -> the workspace default)
        and play it through a ``PlanExecutor`` over a fresh emulator on
        the workspace's link profile — the priced counterpart of
        ``record()``.  Returns the executor report (also appended to
        ``self.replays`` for ``report()``)."""
        from repro.core.replay_passes import PlanExecutor, plan_for
        rec = artifact if artifact is not None else self.compile(kind)
        kind = rec.manifest.get("static", {}).get("kind", kind)
        passes = self.ws.replay_passes if passes is None else passes
        plan = plan_for(rec, passes, jobs=jobs)
        rep = PlanExecutor(netem=self.ws.fresh_netem(),
                           tracer=self.ws.tracer).run(plan)
        self.replays.append((kind, rep))
        return rep

    def attested_replay(self, kind: str = "prefill", *, passes=None,
                        jobs: Optional[int] = None,
                        record_on_miss: bool = False):
        """The end-to-end attested lifecycle leg: proof-verified registry
        fetch (inclusion + consistency against the signed root), verified
        replay-plan execution, and a signed QUOTE binding what ran to
        what was published.  Returns ``(report, quote, proof_bundle)`` —
        the quote + bundle verify offline via
        ``repro.attest.verifier.verify_quote`` with no model or registry
        imports on the verifier side."""
        from repro.core.replay_passes import PlanExecutor, verified_plan
        reg_key = self.key(kind)
        record_fn = self._record_fn(kind, reg_key) if record_on_miss \
            else None
        blob = self.ws.client.fetch(reg_key, record_fn=record_fn)
        passes = self.ws.replay_passes if passes is None else passes
        plan, _rec = verified_plan(blob, self.ws.key, passes, jobs=jobs)
        ex = PlanExecutor(netem=self.ws.fresh_netem(), tracer=self.ws.tracer)
        rep = ex.run(plan)
        self.replays.append((kind, rep))
        head = self.ws.service.signed_head()
        quote = ex.quote(self.ws.keys, recording_key=reg_key, head=head)
        bundle = self.ws.service.proof_for(reg_key)
        self.ws.quotes.append(quote)
        return rep, quote, bundle

    # ------------------------------------------------------------ registry --
    def publish(self, rec: Recording, key: Optional[str] = None) -> dict:
        """Publish into the workspace registry under the canonical key
        (derived from the recording's own static meta), signing with the
        workspace key if the recording is unsigned.  Returns the
        service's wire stats (delta-published)."""
        if not rec.signature:
            rec.sign_with(self.ws.key)
        return self.ws.service.publish(key or self._key_of(rec), rec)

    def _key_of(self, rec: Recording) -> str:
        """Canonical registry key recomputed from the recording's OWN
        identity — static meta, config/mesh fingerprints, and (when the
        recording's name is itself a canonical key) its arch — NOT this
        workload's shapes, so publishing a foreign recording files it
        under its own identity instead of silently shadowing this one."""
        static = rec.manifest.get("static") or {}
        kind = static.get("kind")
        mesh = rec.manifest.get("mesh")
        name = rec.manifest.get("name", "")
        if kind not in KINDS or mesh is None:
            return name
        parts = name.split("/")
        arch = parts[0] if len(parts) == 3 and parts[1] == kind \
            else self.cfg.name
        return key_for(arch, kind,
                       {**static,
                        "config_fp": rec.manifest.get("config_fingerprint",
                                                      "")},
                       fingerprint(mesh))

    def _record_fn(self, kind: str, reg_key: str):
        """Record-on-miss closure: the service's single-flight lease
        supplies the session, so the miss records through the service's
        configured link profile with THIS workload's exact shapes."""
        static = self.static_meta(kind)

        def record_fn(session=None):
            fn, specs, donate = self.step(kind)
            return record(reg_key, fn, specs, mesh=self.mesh,
                          donate_argnums=donate,
                          config_fingerprint=self.config_fp,
                          static_meta=static, session=session)
        return record_fn

    def fetch(self, kind: str = "prefill", *, record_on_miss: bool = False,
              interrupt_after: Optional[int] = None) -> bytes:
        """Chunked/resumable fetch of this workload's recording; the
        returned bytes are HMAC-verified BEFORE they can reach any
        ``pickle.loads``.  ``record_on_miss`` records through the
        service's single-flight lease."""
        reg_key = self.key(kind)
        record_fn = self._record_fn(kind, reg_key) if record_on_miss else None
        return self.ws.client.fetch(reg_key, record_fn=record_fn,
                                    interrupt_after=interrupt_after)

    # ------------------------------------------------------------- serving --
    def _usable(self, meta: dict, static: dict, topo: str) -> bool:
        """An alternate published shape of this workload is substitutable
        iff the engine-visible shapes agree (prefill seq may differ: the
        engine adapts via fixed_prompt_len; decode ignores seq; a
        non-default eos_id is baked into the decode executable) AND it
        was recorded for this exact model config and hardware topology —
        a foreign-host or differently-sized recording would only fail
        later with TopologyMismatch/ReplayArgumentError."""
        static_meta = meta.get("static", {})
        return (all(static_meta.get(f) == static[f]
                    for f in ("batch", "cache_len", "block_k"))
                and static_meta.get("eos_id") == static.get("eos_id")
                and meta.get("config_fingerprint", "") == self.config_fp
                and meta.get("topology", "") == topo)

    def _registry_channel(self, record_on_miss: bool,
                          client=None) -> ReplayChannel:
        """Boot a ReplayChannel from the workspace registry: fetch-by-key
        (chunked, resumable, netem-billed), verify, preload + warm — a
        replica boots from a registry hit without recompiling.  On miss,
        an alternate published shape is substituted when usable, else
        ``record_on_miss`` records through the single-flight lease.

        ``client`` selects WHICH RegistryClient boots the channel: fleet
        replicas pass their own (own netem span, own stats, possibly a
        regional read-replica) so boot billing never aliases onto the
        workspace's shared client; None keeps the shared one."""
        store, service = self.ws.store, self.ws.service
        topo = topology_fingerprint()
        items = []
        for kind in KINDS:
            static = self.static_meta(kind)
            reg_key = self.key(kind)
            record_fn = None
            if not service.has(reg_key):
                found = [(store.entry(fk)["meta"], fk) for fk in
                         store.find(f"{key_arch(self.cfg.name)}/{kind}/")]
                found = [(meta.get("published_s", 0.0), fk)
                         for meta, fk in found
                         if self._usable(meta, static, topo)]
                if found:
                    # most recently published alternate wins — find()
                    # sorts by key hash, which would make it arbitrary
                    reg_key = max(found)[1]
                elif record_on_miss:
                    record_fn = self._record_fn(kind, reg_key)
            items.append((reg_key, record_fn))
        rp = Replayer(key=self.ws.key)
        self.replayers.append(rp)
        if client is None:
            client = self.ws.client
        return client.into_channel(rp, items[0], items[1], warm=True)

    def _live_channel(self) -> LiveChannel:
        """Live-jit transport, memoized: every engine/scheduler built
        from this workload shares the same compiled step functions."""
        if self._live is None:
            cfg, rules = self.cfg, self.rules
            prefill_fn = jax.jit(
                ST.make_prefill_step(cfg, rules, self.cache_len))
            decode_fn = jax.jit(
                ST.make_fused_decode_step(cfg, rules, k=self.block_k,
                                          eos_id=self.eos_id),
                donate_argnums=(3,))
            # grouped right-padded admission: attention families only
            # (decode masks rows >= pos; recurrent state is not
            # position-indexed), and SWA ring layout needs true lengths
            batched_prefill = None
            if cfg.family in ("dense", "moe") and not cfg.sliding_window:
                batched_prefill = jax.jit(
                    ST.make_batched_prefill_step(cfg, rules, self.cache_len))
            self._live = LiveChannel(prefill_fn, decode_fn, batched_prefill)
        return self._live

    def channel(self, *, recordings_dir: str = "",
                record_on_miss: bool = False,
                bill_dispatches: bool = False, client=None):
        """The ExecutionChannel this workload serves through: verified
        registry replay when the workspace has a registry, flat-file
        replay when ``recordings_dir`` is given, live-jit otherwise.
        ``bill_dispatches`` wraps with the netem-billed transport;
        ``client`` boots the registry channel through a specific
        ``RegistryClient`` (a fleet replica's own) instead of the shared
        workspace client."""
        if recordings_dir and self.ws.has_registry:
            raise ValueError(
                "both a workspace registry and recordings_dir were given; "
                "recordings come from exactly one source — use a registry-"
                "less Workspace for flat-file replay")
        if client is not None and not self.ws.has_registry:
            raise ValueError("channel(client=...) requires a workspace "
                             "registry: only registry channels fetch")
        if self.ws.has_registry:
            ch = self._registry_channel(record_on_miss, client=client)
        elif recordings_dir:
            rp = Replayer(key=self.ws.key)
            self.replayers.append(rp)
            pre = rp.load(os.path.join(
                recordings_dir, recording_name(self.cfg.name, "prefill")))
            dec = rp.load(os.path.join(
                recordings_dir, recording_name(self.cfg.name, "decode")))
            rp.warm(dec)    # decode joins the async pipeline with no cold start
            ch = ReplayChannel(rp, pre, dec)
        else:
            ch = self._live_channel()
        if bill_dispatches:
            ch = NetemBilledChannel(ch, self.ws.netem)
        return ch

    def stream_kwargs(self, *, speculate: bool = True,
                      pipeline_depth: int = 4) -> dict:
        return stream_kwargs(self.cfg, n_slots=self.batch,
                             cache_len=self.cache_len, block_k=self.block_k,
                             eos_id=self.eos_id, speculate=speculate,
                             pipeline_depth=pipeline_depth)

    def engine(self, params=None, *, seed: int = 0, channel=None,
               recordings_dir: str = "", record_on_miss: bool = False,
               bill_dispatches: bool = False, speculate: bool = True,
               pipeline_depth: int = 4) -> Engine:
        """One-stream serving behind the classic ``Engine`` facade,
        wired through this workload's channel and the workspace netem."""
        if channel is None:
            channel = self.channel(recordings_dir=recordings_dir,
                                   record_on_miss=record_on_miss,
                                   bill_dispatches=bill_dispatches)
        if params is None:
            params = self.params(seed)
        eng = Engine(params, channel=channel, netem=self.ws.netem,
                     tracer=self.ws.tracer,
                     **self.stream_kwargs(speculate=speculate,
                                          pipeline_depth=pipeline_depth))
        eng.registry_client = self.ws.registry_client
        return eng

    # ----------------------------------------------------------- reporting --
    def replayer_stats(self) -> dict:
        """Summed counters over every Replayer this workload built —
        the fast-path hit/validation split the serving report surfaces."""
        totals: dict = {}
        for rp in self.replayers:
            for k, v in rp.stats.items():
                totals[k] = totals.get(k, 0) + v
        return totals

    def report(self) -> dict:
        return {"arch": self.cfg.name,
                "keys": dict(self._keys),
                "sessions": [dict(rep, kind=kind)
                             for kind, rep in self.sessions],
                "replays": [dict(rep, kind=kind)
                            for kind, rep in self.replays],
                "replayer_stats": self.replayer_stats()}
