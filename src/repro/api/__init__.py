"""repro.api — the one public way to drive the CODY lifecycle.

The paper's pitch is a clean lifecycle: record CPU/GPU interactions once
in a trustworthy environment, then replay them inside the TEE.  This
package is that lifecycle as a fluent, typed API; everything else
(``repro.launch.*`` CLIs, the benchmarks) is argument parsing over it.

    from repro.api import Workspace

    ws = Workspace(registry="/tmp/reg", key=b"secret", net="wifi")
    wl = ws.workload("qwen2.5-3b", cache_len=128, block_k=8, batch=4)

    rec = wl.record("prefill")       # distributed RecordingSession (cloud)
    wl.publish(rec)                  # sign + delta-publish into the registry
    blob = wl.fetch("prefill")       # chunked fetch, verify-before-unpickle
    eng = wl.engine()                # TEE serve: warmed ReplayChannel
    sched, _ = ws.scheduler(["qwen2.5-3b", "xlstm-350m"])   # multi-tenant
    ws.report()                      # netem + registry + session accounting

Module map:

    workspace.py  Workspace — owns the store/service/client, the emulated
                  link (``repro.core.PROFILES``), the signing key, and
                  default record passes; builds workloads, sessions, and
                  multi-tenant schedulers; aggregates accounting.
    workload.py   Workload — one (arch, shapes, mesh): derives the
                  canonical registry key once (``registry.key_for``) and
                  exposes compile/record/publish/fetch/channel/engine;
                  plus the shared step-building helpers (``build_step``,
                  ``static_meta_for``, ``recording_name``,
                  ``stream_kwargs``) the CLIs re-export.

Trust boundaries: ``record``/``compile`` run in the cloud role (model
code + compiler in the TCB); ``publish`` signs what crosses into the
registry; ``fetch`` verifies the HMAC before any ``pickle.loads``;
``channel``/``engine`` in registry mode execute ONLY verified
recordings — no model code, no compiler in the TEE.
"""
from repro.api.workload import (KINDS, Workload, build_step,
                                format_session_report, recording_name,
                                static_meta_for, stream_kwargs)
from repro.api.workspace import Workspace

__all__ = [
    "KINDS", "Workload", "Workspace", "build_step",
    "format_session_report", "recording_name", "static_meta_for",
    "stream_kwargs",
]
