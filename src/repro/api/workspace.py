"""Workspace — the one place the store/service/client/netem/signing-key
wiring lives.

A ``Workspace`` owns everything a lifecycle needs that is NOT specific
to one workload: the registry (content-addressed store + single-flight
record-on-miss service + verify-before-unpickle client), the emulated
device<->cloud link, the signing key, and the default record-session
pass stack.  ``workload()`` binds a model/shape tuple to it;
``scheduler()`` serves several workloads concurrently; ``report()``
aggregates link, registry, and record-session accounting.
"""
from __future__ import annotations

from typing import Optional, Union

from repro.attest import EpochKey, KeySchedule
from repro.configs import get_config, smoke_shrink
from repro.core.attest import RotatedKeyError
from repro.core.netem import PROFILES, NetProfile, NetworkEmulator
from repro.obs.metrics import Metrics
from repro.obs.trace import NULL, Tracer
from repro.fleet.pool import Replica, ReplicaPool
from repro.record import (CloudDryrun, DeviceSlot, RecordCampaign,
                          RecordingSession, VariantSpec)
from repro.registry import (RecordingStore, RegistryClient,
                            RegistryReadReplica, RegistryService)
from repro.serving.scheduler import Scheduler

from repro.api.workload import KINDS, Workload

_Net = Union[None, str, NetProfile, NetworkEmulator]


def _resolve_net(net: _Net) -> Optional[NetworkEmulator]:
    """``net`` can be a profile name from ``repro.core.PROFILES``
    ("local"/"wifi"/"cellular", or "none"), a ``NetProfile``, an existing
    ``NetworkEmulator`` (shared billing with a caller), or None."""
    if net is None or net == "none":
        return None
    if isinstance(net, NetworkEmulator):
        return net
    if isinstance(net, NetProfile):
        return NetworkEmulator(net)
    if net not in PROFILES:
        raise ValueError(f"unknown net profile {net!r}; "
                         f"valid: none|{'|'.join(sorted(PROFILES))}")
    return NetworkEmulator(PROFILES[net])


class Workspace:
    """``Workspace(registry=..., key=..., net="wifi")`` — the lifecycle
    root.  ``registry`` is a filesystem root, ``":memory:"`` for an
    in-process store, or None for live-only serving; ``key`` signs and
    verifies every recording that crosses the registry boundary."""

    def __init__(self, registry: Union[None, str, bool] = None, *,
                 key: Union[bytes, KeySchedule, EpochKey] = b"",
                 net: _Net = None,
                 record_passes="all", replay_passes="all",
                 trace: Union[bool, Tracer] = False,
                 store_cache_bytes: int = 8 << 20):
        if registry is False or registry == "":
            registry = None       # falsy spellings of "no registry"
        # the workspace owns the attestation key schedule (per-epoch
        # signing-key rotation).  ``key`` accepts the raw root secret, a
        # shared KeySchedule, or an EpochKey credential — but NEVER a
        # rotated-away epoch's key: a stale credential must fail loudly
        # at construction, not produce unverifiable signatures later.
        if isinstance(key, EpochKey):
            if key.stale:
                raise RotatedKeyError(
                    f"epoch-{key.epoch} key was rotated away (schedule is "
                    f"at epoch {key.schedule.epoch}); build the Workspace "
                    "from the KeySchedule or the current epoch's key")
            self.keys: Optional[KeySchedule] = key.schedule
            key = key.schedule.root
        elif isinstance(key, KeySchedule):
            self.keys = key
            key = key.root
        else:
            self.keys = KeySchedule(key) if key else None
        if registry is not None and not key:
            raise ValueError(
                "Workspace with a registry requires the signing key: "
                "recordings are verified before any unpickle, so an "
                "unkeyed registry workspace could never fetch safely")
        self.key = key
        self.quotes = []          # replay attestation quotes emitted
        self.registry = registry
        self.netem = _resolve_net(net)
        self.record_passes = record_passes
        self.replay_passes = replay_passes
        self.workloads = []
        self.schedulers = []
        self.fleets = []
        self.campaigns = []
        self.store_cache_bytes = store_cache_bytes
        self.metrics = Metrics()
        # trace=True builds a Tracer on the workspace link's virtual clock
        # (constant 0 base when there is no link — scoped components rebase
        # their own emulators); trace=False leaves the falsy NULL tracer so
        # every traced() call site is a single truthiness check
        if isinstance(trace, Tracer):
            self.tracer = trace
        elif trace:
            net_ref = self.netem
            self.tracer = Tracer(
                clock=(lambda: net_ref.virtual_time_s)
                if net_ref is not None else None)
        else:
            self.tracer = NULL
        self._store: Optional[RecordingStore] = None
        self._service: Optional[RegistryService] = None
        self._client: Optional[RegistryClient] = None
        self._read_replicas: dict = {}     # region -> RegistryReadReplica

    # ------------------------------------------------------------- wiring --
    @property
    def has_registry(self) -> bool:
        return self.registry is not None

    @property
    def profile(self) -> Optional[NetProfile]:
        return self.netem.profile if self.netem is not None else None

    def fresh_netem(self) -> Optional[NetworkEmulator]:
        """A new emulator on the workspace's profile — for callers that
        need an isolated billing span (e.g. per-scenario benchmarks)."""
        return NetworkEmulator(self.profile) if self.netem is not None \
            else None

    @property
    def store(self) -> RecordingStore:
        if not self.has_registry:
            raise RuntimeError("Workspace has no registry configured; "
                               "pass registry=<root> (or ':memory:')")
        if self._store is None:
            root = None if self.registry in (True, ":memory:") \
                else self.registry
            self._store = RecordingStore(
                root, key=self.key, cache_bytes=self.store_cache_bytes,
                metrics=self.metrics)
        return self._store

    @property
    def service(self) -> RegistryService:
        """Cloud side: fetch-by-key + single-flight record-on-miss over
        the workspace link profile + delta publishing."""
        if self._service is None:
            self._service = RegistryService(
                self.store, signing_key=self.key,
                record_profile=self.profile,
                record_passes=self.record_passes, tracer=self.tracer,
                keys=self.keys)
        return self._service

    @property
    def client(self) -> RegistryClient:
        """Device side: chunked resumable netem-billed fetch,
        HMAC-verify-before-unpickle."""
        if self._client is None:
            self._client = self.new_client()
        return self._client

    @property
    def registry_client(self) -> Optional[RegistryClient]:
        """The shared client if one has been created, else None — for
        callers that only want to read its stats."""
        return self._client

    def new_client(self, netem: Optional[NetworkEmulator] = None, *,
                   region: Optional[str] = None,
                   verify_proofs: bool = True) -> RegistryClient:
        """A fresh client against this workspace's service (its own
        fetch cache; optionally its own emulator).  With ``region`` the
        client reads through that region's read-replica instead of the
        primary, so its chunk traffic is absorbed by the regional cache.
        ``verify_proofs=False`` opts out of transparency-log proof
        verification (the overhead benchmark's baseline arm).

        Each call returns a FULLY independent client — its own ``stats``
        counter and its own chunk LRU — so per-replica billing spans
        never alias (the fleet regression test pins this)."""
        svc = self.read_replica(region) if region is not None \
            else self.service
        return RegistryClient(svc,
                              netem=netem if netem is not None
                              else self.netem, key=self.key,
                              tracer=self.tracer, keys=self.keys,
                              verify_proofs=verify_proofs)

    def read_replica(self, region: str) -> RegistryReadReplica:
        """The (memoized) read-replica for ``region``: a regional chunk
        cache over the primary service — N replicas booting the same key
        in one region pull its chunks from the primary once."""
        if region not in self._read_replicas:
            self._read_replicas[region] = RegistryReadReplica(
                self.service, region=region, metrics=self.metrics)
        return self._read_replicas[region]

    # -------------------------------------------------------- attestation --
    def rotate_epoch(self) -> int:
        """Advance the signing-key schedule one epoch.  Heads and quotes
        signed from now on carry the new epoch; everything published in
        older epochs stays verifiable (the schedule keeps its history)."""
        if self.keys is None:
            raise ValueError("Workspace has no key schedule to rotate "
                             "(construct with key=...)")
        return self.keys.rotate()

    # ------------------------------------------------------------- record --
    def session(self, passes=None, jobs: Optional[int] = None
                ) -> RecordingSession:
        """One two-party recording session over the workspace's link
        profile (in-process degenerate when the workspace has no net).
        Sessions are single-use: one per recording."""
        passes = self.record_passes if passes is None else passes
        cloud = CloudDryrun(jobs=jobs) if jobs is not None else None
        if self.netem is not None:
            return RecordingSession.for_profile(self.profile, passes=passes,
                                                cloud=cloud,
                                                tracer=self.tracer)
        return RecordingSession.local(passes=passes, cloud=cloud,
                                      tracer=self.tracer)

    def campaign(self, items, *, devices: int = 2, nets=None,
                 hw_class: str = "edge-gpu", share_history: bool = True,
                 passes=None, jobs: Optional[int] = None,
                 tick_s: float = 0.02, name: Optional[str] = None,
                 publish: Optional[bool] = None,
                 artifacts: Optional[dict] = None,
                 max_ticks: int = 500_000) -> RecordCampaign:
        """Multi-device record fan-out: a ``RecordCampaign`` over this
        workspace's registry and link profile.

        ``items`` are ``Workload``s (expanded over every kind),
        ``(Workload, kind)`` pairs, or prepared ``VariantSpec``s.  Each of
        the ``devices`` slots gets its OWN emulator — per-device billing
        never aliases — on the workspace profile, or round-robin over
        ``nets`` (profile names / ``NetProfile``s).  ``publish`` defaults
        to whether the workspace has a registry: claimed variants then go
        through the multi-variant lease and publish incrementally.  The
        campaign is returned un-run; call ``.run()``."""
        variants = []
        for it in items:
            if isinstance(it, VariantSpec):
                variants.append(it)
                continue
            wl, kinds = (it if isinstance(it, tuple) else (it, None))
            for kind in ([kinds] if isinstance(kinds, str)
                         else (kinds or KINDS)):
                variants.append(VariantSpec(
                    wl.key(kind),
                    (lambda w=wl, k=kind: w.compile(k)),
                    label=f"{wl.cfg.name}/{kind}/"
                          f"b{wl.static_meta(kind)['batch']}"
                          f"s{wl.seq if kind == 'prefill' else '-'}"))
        net_specs = list(nets) if nets else [None]
        slots = []
        for i in range(devices):
            spec = net_specs[i % len(net_specs)]
            netem = self.fresh_netem() if spec is None \
                else _resolve_net(spec)
            slots.append(DeviceSlot(f"dev{i}", netem, hw_class=hw_class))
        if publish is None:
            publish = self.has_registry
        c = RecordCampaign(
            variants, slots, share_history=share_history,
            artifacts=artifacts,
            passes=self.record_passes if passes is None else passes,
            jobs=jobs, tick_s=tick_s,
            name=name if name is not None
            else f"campaign{len(self.campaigns)}",
            tracer=self.tracer, metrics=self.metrics,
            service=self.service if publish else None,
            max_ticks=max_ticks)
        self.campaigns.append(c)
        return c

    # ---------------------------------------------------------- workloads --
    def workload(self, arch, *, shapes: Optional[dict] = None, mesh=None,
                 smoke: bool = True, **shape_overrides) -> Workload:
        """Bind a model to this workspace.  ``arch`` is a config name
        (smoke-shrunk by default) or an already-built ``ModelConfig``;
        shape kwargs (``cache_len``, ``block_k``, ``batch``,
        ``prefill_batch``, ``seq``, ``eos_id``) come from ``shapes`` or
        directly as keyword overrides."""
        cfg = arch
        if isinstance(arch, str):
            cfg = get_config(arch)
            if smoke:
                cfg = smoke_shrink(cfg)
        kw = dict(shapes or {})
        kw.update(shape_overrides)
        wl = Workload(self, cfg, mesh=mesh, **kw)
        self.workloads.append(wl)
        return wl

    def scheduler(self, streams, *, n_slots: int = 4, cache_len: int = 128,
                  block_k: int = 8, eos_id: int = 2, smoke: bool = True,
                  speculate: bool = True, pipeline_depth: int = 4,
                  max_live_slots=None, stall_limit=None, seed: int = 0):
        """Multi-tenant serving: one ``Scheduler``, one stream per entry
        of ``streams``, each with its own channel, params (seeded
        ``seed + i``), slots, and caches.  An entry is an arch name —
        shaped by the ``n_slots``/``cache_len``/``block_k``/``eos_id``/
        ``smoke`` kwargs — or a prepared ``Workload``, which KEEPS its
        own shapes (it is already an identity; the kwargs do not apply).
        Returns ``(scheduler, {name: workload})``."""
        sched = Scheduler(netem=self.netem, max_live_slots=max_live_slots,
                          stall_limit=stall_limit, tracer=self.tracer,
                          metrics=self.metrics)
        self.schedulers.append(sched)
        out = {}
        for i, s in enumerate(streams):
            wl = s if isinstance(s, Workload) else self.workload(
                s, smoke=smoke, batch=n_slots, cache_len=cache_len,
                block_k=block_k, eos_id=eos_id)
            sched.add_stream(wl.cfg.name, wl.channel(), wl.params(seed + i),
                             **wl.stream_kwargs(speculate=speculate,
                                                pipeline_depth=pipeline_depth))
            out[wl.cfg.name] = wl
        return sched, out

    def fleet(self, streams, *, replicas: int = 2,
              policy: str = "round_robin", name: Optional[str] = None,
              tick_s: float = 0.02, regions: int = 1,
              record_on_miss: bool = False, pending_limit: int = 8,
              queue_limit: Optional[int] = None, autoscale: bool = False,
              queue_high: int = 8, sustain_ticks: int = 5,
              idle_ticks: int = 50, boot_ticks: int = 10,
              min_replicas: int = 1, max_replicas: int = 8,
              seed: int = 0, smoke: bool = True, n_slots: int = 4,
              cache_len: int = 128, block_k: int = 8, eos_id: int = 2,
              speculate: bool = True, pipeline_depth: int = 4,
              validate_every: int = 1, max_ticks: int = 500_000):
        """Fleet-scale serving: a ``ReplicaPool`` whose replicas each boot
        warm from the registry on their OWN netem billing span and their
        own ``RegistryClient`` (no stats aliasing between replicas).  With
        ``regions > 1`` replica ``idx`` reads through read-replica
        ``"r{idx % regions}"`` so a popular key fans out CDN-style.
        ``streams`` entries are arch names or prepared ``Workload``s, as
        in ``scheduler()``.  Returns ``(pool, {name: workload})``."""
        workloads = {}
        for i, s in enumerate(streams):
            wl = s if isinstance(s, Workload) else self.workload(
                s, smoke=smoke, batch=n_slots, cache_len=cache_len,
                block_k=block_k, eos_id=eos_id)
            workloads[wl.cfg.name] = (i, wl)
        pool_name = name if name is not None else f"fleet{len(self.fleets)}"

        def factory(idx: int) -> Replica:
            netem = self.fresh_netem()
            client = None
            if self.has_registry:
                region = f"r{idx % regions}" if regions > 1 else None
                client = self.new_client(netem=netem, region=region)
            boot_mark = netem.virtual_time_s if netem is not None else 0.0
            sched = Scheduler(netem=netem, tracer=self.tracer,
                              metrics=self.metrics)
            for tenant, (i, wl) in workloads.items():
                ch = wl.channel(record_on_miss=record_on_miss,
                                client=client) if self.has_registry \
                    else wl.channel()
                sched.add_stream(
                    tenant, ch, wl.params(seed + i),
                    **wl.stream_kwargs(speculate=speculate,
                                       pipeline_depth=pipeline_depth))
            boot_s = (netem.virtual_time_s - boot_mark) \
                if netem is not None else 0.0
            return Replica(f"{pool_name}-{idx}", sched, netem=netem,
                           boot_virtual_s=boot_s, region=idx % regions,
                           pending_limit=pending_limit,
                           validate_every=validate_every)

        pool = ReplicaPool(
            factory, replicas=replicas, policy=policy, name=pool_name,
            tick_s=tick_s, queue_limit=queue_limit, autoscale=autoscale,
            queue_high=queue_high, sustain_ticks=sustain_ticks,
            idle_ticks=idle_ticks, boot_ticks=boot_ticks,
            min_replicas=min_replicas, max_replicas=max_replicas,
            metrics=self.metrics, labels={"pool": pool_name},
            max_ticks=max_ticks)
        self.fleets.append(pool)
        return pool, {n: wl for n, (_i, wl) in workloads.items()}

    # ----------------------------------------------------------- reporting --
    def report(self) -> dict:
        """Aggregate accounting: the link emulator's totals, registry
        client/service stats, every record-session report made through
        this workspace's workloads, the metrics registry snapshot
        (latency quantiles and all), and each scheduler's public stats.
        The shape is pinned by ``repro.obs.schema.check_workspace_report``
        so fields can't silently vanish."""
        return {
            "net": self.netem.snapshot() if self.netem is not None else None,
            "registry_client": dict(self._client.stats)
            if self._client is not None else {},
            "registry_service": dict(self._service.stats)
            if self._service is not None else {},
            "sessions": [dict(rep, workload=wl.cfg.name, kind=kind)
                         for wl in self.workloads
                         for kind, rep in wl.sessions],
            "replays": [dict(rep, workload=wl.cfg.name, kind=kind)
                        for wl in self.workloads
                        for kind, rep in wl.replays],
            "replayer_stats": self._replayer_stats(),
            "metrics": self.metrics.snapshot(),
            "schedulers": [s.stats() for s in self.schedulers],
            "fleet": [p.stats() for p in self.fleets],
            "campaigns": [c.stats() for c in self.campaigns],
            "registry_store": self._registry_store_stats(),
            "attest": self._attest_stats(),
        }

    def _attest_stats(self) -> dict:
        """Attestation accounting: key-schedule epoch, transparency-log
        head, client proof verifications, quotes emitted."""
        cl = self._client.stats if self._client is not None else {}
        return {
            "epoch": self.keys.epoch if self.keys is not None else None,
            "log_size": self._service.log.size
            if self._service is not None else 0,
            "root": self._service.log.root()
            if self._service is not None else None,
            "quotes": len(self.quotes),
            "proofs_verified": int(cl.get("proofs_verified", 0)),
            "proof_bytes": int(cl.get("proof_bytes", 0)),
        }

    def _registry_store_stats(self) -> dict:
        """Store-level accounting (chunk reads, LRU cache counters) plus
        each regional read-replica's summary — the satellite observability
        for CDN-style fan-out."""
        base = self._store.summary() if self._store is not None else \
            {"chunk_reads": 0, "puts": 0, "gets": 0, "cache": None}
        base["read_replicas"] = [
            self._read_replicas[r].summary()
            for r in sorted(self._read_replicas)]
        return base

    def _replayer_stats(self) -> dict:
        """Summed Replayer counters across every workload — the serving-
        level fast-path hit vs slow-validation split."""
        totals: dict = {}
        for wl in self.workloads:
            for k, v in wl.replayer_stats().items():
                totals[k] = totals.get(k, 0) + v
        return totals
