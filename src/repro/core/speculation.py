"""Commit speculation with history-based prediction (paper §4.2).

DriverShim predicts the read values of a commit when the last ``k``
commits at the same site returned identical values; execution continues on
the prediction and is validated when the real values arrive.  Misprediction
triggers rollback-via-replay: both sides restart from the last validated
point and fast-forward the interaction log (no network needed).

``HistorySpeculator`` is the predictor; ``SpeculativeRunner`` drives a
CommitQueue with speculation + validation + rollback, and collects the
paper's Fig. 8 statistics (commit categories, speculation hit rates).
"""
from __future__ import annotations

import collections
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.deferral import CommitQueue, Op


class MispredictError(Exception):
    def __init__(self, site, predicted, actual):
        super().__init__(f"mispredict @ {site}: {predicted} != {actual}")
        self.site = site
        self.predicted = predicted
        self.actual = actual


class HistorySpeculator:
    """Predict commit outcomes from k identical historical outcomes.

    One speculator may be SHARED across serving streams: histories are
    keyed by ``(stream, site-sequence)``, so a multi-tenant scheduler
    gets per-stream prediction dynamics identical to serving each stream
    alone (tenant isolation — histories never mix)."""

    def __init__(self, k: int = 3):
        self.k = k
        self.history: Dict[str, collections.deque] = {}
        self.stats = collections.Counter()

    def _key(self, ops: List[Op], stream: str = "") -> str:
        sites = "|".join(f"{o.kind}:{o.site}" for o in ops)
        return f"{stream}::{sites}" if stream else sites

    def predict(self, ops: List[Op], stream: str = "") -> Optional[Tuple]:
        self.stats["predicts"] += 1
        key = self._key(ops, stream)
        h = self.history.get(key)
        if h is None or len(h) < self.k:
            self.stats["no_history"] += 1
            return None
        vals = list(h)[-self.k:]
        if all(v == vals[0] for v in vals):
            self.stats["predicted"] += 1
            return vals[0]
        self.stats["low_confidence"] += 1
        return None

    def record(self, ops: List[Op], outcome: Tuple, stream: str = ""):
        self.stats["records"] += 1
        key = self._key(ops, stream)
        self.history.setdefault(key, collections.deque(maxlen=16)).append(
            tuple(outcome))

    def hit_rate(self) -> float:
        """Fraction of ``predict()`` calls that produced a usable
        prediction — the shared-history lift metric the record fan-out
        campaign reports per (hw_class, device)."""
        n = self.stats["predicts"]
        return (self.stats["predicted"] / n) if n else 0.0


class SpeculativeRunner:
    """Speculative commits over a CommitQueue.

    ``checkpoint_fn()`` captures a restartable snapshot (metastate only —
    cheap); ``rollback_fn(snapshot, log)`` restores and fast-forwards, the
    paper's replay-based recovery.  Validation of outstanding commits
    happens at ``sync()`` (the paper's externalization points) or when a
    dependent commit must not spill speculative state (§4.2 optimization).
    """

    def __init__(self, queue: CommitQueue, speculator: HistorySpeculator,
                 checkpoint_fn: Callable[[], Any],
                 rollback_fn: Callable[[Any, List[Op]], None]):
        self.q = queue
        self.spec = speculator
        self.checkpoint_fn = checkpoint_fn
        self.rollback_fn = rollback_fn
        self.outstanding: List[Tuple[List[Op], Tuple, Any]] = []
        self.stats = collections.Counter()

    def commit_speculative(self) -> bool:
        """Try to commit the queued ops with predicted read values.

        On success the commit is shipped ASYNCHRONOUSLY via
        ``CommitQueue.commit_async`` (device executes it; no blocking round
        trip — paper fig. 5c) and execution continues on the prediction;
        validation happens at ``sync()``.  Shipping goes through the ONE
        queue path, so in-batch symbol resolution and netem byte accounting
        are identical to a synchronous commit."""
        ops = list(self.q.queue)
        reads = [o for o in ops if o.symbol is not None]
        pred = self.spec.predict(ops) if reads else None
        if pred is None or len(pred) != len(reads):
            res = self.q.commit()           # synchronous fallback (1 RTT)
            self.spec.record(ops, tuple(res))
            self.stats["sync_commits"] += 1
            return False
        snapshot = self.checkpoint_fn()
        actual = self.q.commit_async()      # ships now; host does not stall
        self.outstanding.append((ops, tuple(pred), tuple(actual), snapshot))
        self.stats["spec_commits"] += 1
        return True

    def sync(self):
        """Validate all outstanding speculative commits (in order) — the
        paper's externalization barrier.  The ops were already logged and
        counted by ``commit_async``; this only compares prediction against
        the arrived values and rolls back on divergence."""
        while self.outstanding:
            ops, pred, actual, snapshot = self.outstanding.pop(0)
            self.spec.record(ops, actual)
            if pred != actual:
                self.stats["mispredicts"] += 1
                self.rollback_fn(snapshot, list(self.q.log))
                self.outstanding.clear()
                raise MispredictError(ops[0].site if ops else "?",
                                      pred, actual)
            self.stats["validated"] += 1
