"""ExecutionChannel — the transport seam between serving and the device.

``repro.core.deferral`` already states the contract: the channel is any
in-order executor of host<->device interactions.  This module makes that
seam a first-class object so the serving stack (scheduler / stream
executors / commit frontier) is transport-agnostic.  Three transports
share one interface:

  * ``LiveChannel``     — live jitted callables (the cloud / record role);
  * ``ReplayChannel``   — signed recordings through a ``Replayer`` (the
                          paper's in-TEE mode).  TRUST BOUNDARY: this
                          module imports NO model/config/training code, so
                          a replay channel reaches decode with nothing but
                          verified executables in the TCB;
  * ``NetemBilledChannel`` — wraps another channel, billing every dispatch
                          to a ``NetworkEmulator`` and logging the
                          interaction trace (site + input avals): the
                          record/emulation transport the paper uses to
                          price the distributed-driver link.

A channel exposes the three step kinds the serving runtime dispatches —
``prefill``, ``batched_prefill`` (optional capability), and
``decode_block``.  The ``CommitQueue`` side of a stream stays with the
``StreamExecutor`` (an op's meaning — dispatch vs. in-flight readback —
is executor state); the channel is purely the step transport.
"""
from __future__ import annotations

from typing import Callable, List, Optional


class ChannelCapabilityError(NotImplementedError):
    """The channel does not implement the requested step kind."""


class ExecutionChannel:
    """Transport endpoint executing serving steps in program order.

    ``kind`` names the transport; ``fixed_prompt_len`` is non-None when
    the transport only accepts one prefill shape (recorded executables);
    ``supports_batched_prefill`` gates grouped right-padded admission.
    """

    kind = "abstract"

    @property
    def fixed_prompt_len(self) -> Optional[int]:
        return None

    @property
    def supports_batched_prefill(self) -> bool:
        return False

    def prefill(self, params, batch):
        raise ChannelCapabilityError(f"{self.kind}: prefill")

    def batched_prefill(self, params, tokens, lengths):
        raise ChannelCapabilityError(f"{self.kind}: batched_prefill")

    def decode_block(self, params, tokens, pos, caches):
        raise ChannelCapabilityError(f"{self.kind}: decode_block")


class LiveChannel(ExecutionChannel):
    """Live-jit transport: wraps already-built callables.

    The callables are typically ``jax.jit`` products, but anything with
    the step signatures works — which is what lets the Engine facade and
    the tests inject wrapped/fault-injecting steps unchanged.
    """

    kind = "live-jit"

    def __init__(self, prefill_fn: Callable, decode_fn: Callable,
                 batched_prefill_fn: Optional[Callable] = None,
                 fixed_prompt_len: Optional[int] = None):
        self._prefill = prefill_fn
        self._decode = decode_fn
        self._batched_prefill = batched_prefill_fn
        self._fixed_prompt_len = fixed_prompt_len

    @property
    def fixed_prompt_len(self) -> Optional[int]:
        return self._fixed_prompt_len

    @property
    def supports_batched_prefill(self) -> bool:
        return self._batched_prefill is not None

    def prefill(self, params, batch):
        return self._prefill(params, batch)

    def batched_prefill(self, params, tokens, lengths):
        if self._batched_prefill is None:
            raise ChannelCapabilityError(f"{self.kind}: batched_prefill")
        return self._batched_prefill(params, tokens, lengths)

    def decode_block(self, params, tokens, pos, caches):
        return self._decode(params, tokens, pos, caches)


class ReplayChannel(ExecutionChannel):
    """Signed-replay transport: executes verified recordings only.

    Holds a ``Replayer`` plus the logical names of the prefill/decode
    recordings.  Prefill shape is pinned by the recording (``seq`` in the
    manifest's static meta); batched prefill is structurally unsupported —
    a recorded executable has exactly the shapes it was recorded with.
    """

    kind = "signed-replay"

    def __init__(self, replayer, prefill_name: str, decode_name: str):
        self._rp = replayer
        self._pre = prefill_name
        self._dec = decode_name

    @property
    def replayer(self):
        return self._rp

    @property
    def fixed_prompt_len(self) -> Optional[int]:
        # several prefill shape-bucket variants may share the logical name;
        # the prompt length is only "fixed" when every variant agrees
        seqs = {m.get("static", {}).get("seq")
                for m in self._rp.manifests(self._pre)}
        if len(seqs) == 1:
            seq = seqs.pop()
            return int(seq) if seq else None
        return None

    def prefill(self, params, batch):
        return self._rp.execute(self._pre, params, batch)

    def decode_block(self, params, tokens, pos, caches):
        return self._rp.execute(self._dec, params, tokens, pos, caches)


class NetemBilledChannel(ExecutionChannel):
    """Record/emulation transport: every dispatch crosses the emulated
    link and lands in the interaction log.

    Dispatches ship as ASYNC trips (commands are metastate-sized and the
    distributed driver does not stall on a dispatch — paper fig. 5c); the
    log rows ``(step, site-ish arg summary)`` are the recording trace a
    record phase persists.  Wrap any inner channel: a ``LiveChannel`` for
    record mode, a ``ReplayChannel`` for priced replay emulation.
    """

    kind = "netem-billed"
    DISPATCH_BYTES = 256          # command + descriptor metastate per step

    def __init__(self, inner: ExecutionChannel, netem):
        self.inner = inner
        self.netem = netem
        self.log: List[tuple] = []

    @property
    def fixed_prompt_len(self) -> Optional[int]:
        return self.inner.fixed_prompt_len

    @property
    def supports_batched_prefill(self) -> bool:
        return self.inner.supports_batched_prefill

    def _bill(self, step: str, *shaped):
        self.log.append((step, tuple(
            (tuple(getattr(a, "shape", ())), str(getattr(a, "dtype", "")))
            for a in shaped)))
        if self.netem is not None:
            self.netem.async_trip(send_bytes=self.DISPATCH_BYTES,
                                  recv_bytes=0)

    def prefill(self, params, batch):
        self._bill("prefill", *(batch.values() if isinstance(batch, dict)
                                else (batch,)))
        return self.inner.prefill(params, batch)

    def batched_prefill(self, params, tokens, lengths):
        self._bill("batched_prefill", tokens, lengths)
        return self.inner.batched_prefill(params, tokens, lengths)

    def decode_block(self, params, tokens, pos, caches):
        self._bill("decode_block", tokens, pos)
        return self.inner.decode_block(params, tokens, pos, caches)
