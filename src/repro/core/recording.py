"""Recording format — the TPU analogue of the paper's CPU/GPU interaction log.

A recording is a signed, self-describing artifact containing:
  * manifest   — workload/config/mesh fingerprints, I/O avals + shardings,
                 donation map, cost/memory analysis (the paper's job
                 metadata), creation info;
  * payload    — the serialized XLA executable
                 (jax.experimental.serialize_executable), i.e. the exact
                 "stimuli script" the accelerator will execute;
  * signature  — HMAC-SHA256 over manifest+payload.

The replayer (repro.core.replay) verifies the signature and the topology
fingerprint before loading; it never retraces or recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import msgpack

from repro.core.attest import (TamperedRecordingError,
                               UnverifiedRecordingError, sign, verify)

FORMAT_VERSION = 2


@dataclasses.dataclass
class Recording:
    manifest: Dict[str, Any]
    payload: bytes                 # serialized executable
    trees: bytes                   # pickled (in_tree, out_tree)
    signature: str = ""

    def signable(self) -> bytes:
        return msgpack.packb({"m": self.manifest}, use_bin_type=True) + \
            self.payload + self.trees

    def sign_with(self, key: bytes) -> "Recording":
        self.signature = sign(self.signable(), key)
        return self

    def to_bytes(self) -> bytes:
        return msgpack.packb({
            "v": FORMAT_VERSION, "manifest": self.manifest,
            "payload": self.payload, "trees": self.trees,
            "signature": self.signature}, use_bin_type=True)

    @staticmethod
    def from_bytes(blob: bytes, key: Optional[bytes] = None, *,
                   allow_unsigned: bool = False) -> "Recording":
        """Parse + verify a recording.  HMAC verification is NOT optional:
        loading without a key (i.e. skipping verification of bytes that
        will later reach ``pickle.loads``) requires ``allow_unsigned=True``
        as an explicit, greppable opt-in."""
        if key is None and not allow_unsigned:
            raise UnverifiedRecordingError(
                "Recording.from_bytes without a signing key skips HMAC "
                "verification before untrusted deserialization; pass "
                "key=... or opt in explicitly with allow_unsigned=True")
        try:
            d = msgpack.unpackb(blob, raw=False)
            if d.get("v") != FORMAT_VERSION:
                raise TamperedRecordingError(f"format version {d.get('v')}")
            rec = Recording(d["manifest"], d["payload"], d["trees"],
                            d["signature"])
        except TamperedRecordingError:
            raise
        except Exception as e:  # corrupted framing == tampering
            raise TamperedRecordingError(f"unparseable recording: {e}")
        if key is not None and not verify(rec.signable(), rec.signature, key):
            raise TamperedRecordingError("signature verification failed")
        return rec

    def save(self, path: str, key: bytes):
        self.sign_with(key)
        with open(path, "wb") as f:
            f.write(self.to_bytes())

    @staticmethod
    def load(path: str, key: Optional[bytes] = None, *,
             allow_unsigned: bool = False) -> "Recording":
        with open(path, "rb") as f:
            return Recording.from_bytes(f.read(), key,
                                        allow_unsigned=allow_unsigned)
