"""Metastate-only synchronization (paper §5).

The paper synchronizes only GPU *metastate* (commands, shaders, job
descriptors) between the distributed driver and GPU — never program data —
and ships compressed deltas between consecutive sync points.

Here the same split governs every cross-host/persistence path:
  * metastate    — step counters, positions, RNG keys, page tables, done
                   masks, sampler state, schedules: small, integer-ish,
                   latency-critical;
  * program data — weights, optimizer moments, KV pages, activations: big,
                   bandwidth-bound, moved by collectives / chunk store only.

``split``/``merge`` partition a pytree; ``DeltaSync`` ships only changed
leaves, zlib-compressed (the paper's range-coder + delta, §5).
"""
from __future__ import annotations

import hashlib
import io
import re
import zlib
from typing import Any, Dict, Tuple

import jax
import msgpack
import numpy as np

META_MAX_ELEMS = 4096     # leaves larger than this are program data
_META_HINTS = ("pos", "step", "rng", "page", "done", "length", "count",
               "slot", "id", "mask")
_TOKEN_SPLIT = re.compile(r"[^a-z0-9]+")


def _path_tokens(path: str) -> Tuple[str, ...]:
    """Split a keystr path into name tokens: ``['hidden_mask'][0]`` ->
    ('hidden', 'mask', '0').  Hints match whole tokens (plural allowed),
    never substrings — ``"id" in "hidden"`` must not classify a weight
    leaf as metastate."""
    return tuple(t for t in _TOKEN_SPLIT.split(path.lower()) if t)


def is_metastate(path: str, leaf) -> bool:
    arr = np.asarray(leaf)
    if any(t in _META_HINTS or t.rstrip("s") in _META_HINTS
           for t in _path_tokens(path)):
        return True
    if np.issubdtype(arr.dtype, np.integer) or arr.dtype == np.bool_:
        return arr.size <= META_MAX_ELEMS * 64
    return arr.size <= META_MAX_ELEMS


def _paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(kp): v for kp, v in flat}


def split(tree) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """tree -> (metastate dict, program-data dict), both path-keyed."""
    meta, data = {}, {}
    for path, leaf in _paths(tree).items():
        (meta if is_metastate(path, leaf) else data)[path] = leaf
    return meta, data


def merge(tree_like, meta: Dict[str, Any], data: Dict[str, Any]):
    """Rebuild a pytree with the same structure from the two halves."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for kp, old in flat:
        path = jax.tree_util.keystr(kp)
        out.append(meta.get(path, data.get(path, old)))
    return jax.tree_util.tree_unflatten(treedef, [v for v in out])


def _pack_leaf(v) -> bytes:
    arr = np.asarray(v)
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _unpack_leaf(b: bytes):
    return np.load(io.BytesIO(b), allow_pickle=False)


def _digest(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()[:16]


class DeltaSync:
    """Delta + compression sync of a path-keyed metastate dict."""

    def __init__(self):
        self._last: Dict[str, str] = {}
        self.stats = {"syncs": 0, "bytes_raw": 0, "bytes_wire": 0,
                      "leaves_sent": 0, "leaves_skipped": 0}

    def pack(self, meta: Dict[str, Any]) -> bytes:
        changed = {}
        for path, leaf in meta.items():
            blob = _pack_leaf(leaf)
            d = _digest(blob)
            self.stats["bytes_raw"] += len(blob)
            if self._last.get(path) != d:
                changed[path] = blob
                self._last[path] = d
                self.stats["leaves_sent"] += 1
            else:
                self.stats["leaves_skipped"] += 1
        wire = zlib.compress(msgpack.packb(changed, use_bin_type=True), 6)
        self.stats["syncs"] += 1
        self.stats["bytes_wire"] += len(wire)
        return wire

    @staticmethod
    def unpack(wire: bytes, base: Dict[str, Any]) -> Dict[str, Any]:
        changed = msgpack.unpackb(zlib.decompress(wire), raw=False)
        out = dict(base)
        for path, blob in changed.items():
            out[path] = _unpack_leaf(blob)
        return out


def full_pack(tree) -> bytes:
    """Naive baseline: ship EVERYTHING (paper's 'Naive' MemSync column)."""
    blobs = {p: _pack_leaf(v) for p, v in _paths(tree).items()}
    return zlib.compress(msgpack.packb(blobs, use_bin_type=True), 1)
