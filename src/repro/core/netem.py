"""Network emulator (the paper's NetEm setup, §7.2) — virtual-time model of
the cloud<->client link so record-phase benchmarks reproduce Fig. 7 /
Table 1 quantitatively on this CPU-only container.

WiFi-like:     RTT 20 ms, BW 80 Mbps
cellular-like: RTT 50 ms, BW 40 Mbps
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class NetProfile:
    name: str
    rtt_s: float
    bw_bytes_s: float


WIFI = NetProfile("wifi", 0.020, 80e6 / 8)
CELLULAR = NetProfile("cellular", 0.050, 40e6 / 8)
LOCAL = NetProfile("local", 2e-6, 10e9)  # same-SoC reference


class NetworkEmulator:
    def __init__(self, profile: NetProfile):
        self.profile = profile
        self.reset()

    def reset(self):
        self.virtual_time_s = 0.0
        self.round_trips = 0          # BLOCKING round trips (paper Table 1)
        self.async_trips = 0          # speculative commits: wire, no stall
        self.bytes_sent = 0
        self.bytes_received = 0

    def round_trip(self, send_bytes: int = 64, recv_bytes: int = 64):
        """One synchronous request/response over the link."""
        self.round_trips += 1
        self.bytes_sent += send_bytes
        self.bytes_received += recv_bytes
        self.virtual_time_s += self.profile.rtt_s + \
            (send_bytes + recv_bytes) / self.profile.bw_bytes_s

    def async_trip(self, send_bytes: int = 256, recv_bytes: int = 64):
        """Asynchronous commit: consumes bandwidth but hides the RTT."""
        self.async_trips += 1
        self.bytes_sent += send_bytes
        self.bytes_received += recv_bytes
        self.virtual_time_s += (send_bytes + recv_bytes) / self.profile.bw_bytes_s

    def one_way(self, nbytes: int):
        self.bytes_sent += nbytes
        self.virtual_time_s += self.profile.rtt_s / 2 + \
            nbytes / self.profile.bw_bytes_s

    def snapshot(self) -> dict:
        return {"time_s": self.virtual_time_s, "round_trips": self.round_trips,
                "bytes": self.bytes_sent + self.bytes_received}
