"""Network emulator (the paper's NetEm setup, §7.2) — virtual-time model of
the cloud<->client link so record-phase benchmarks reproduce Fig. 7 /
Table 1 quantitatively on this CPU-only container.

WiFi-like:     RTT 20 ms, BW 80 Mbps
cellular-like: RTT 50 ms, BW 40 Mbps
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class NetProfile:
    name: str
    rtt_s: float
    bw_bytes_s: float


WIFI = NetProfile("wifi", 0.020, 80e6 / 8)
CELLULAR = NetProfile("cellular", 0.050, 40e6 / 8)
LOCAL = NetProfile("local", 2e-6, 10e9)  # same-SoC reference

# the one name -> profile registry every CLI/bench resolves --net through
PROFILES = {p.name: p for p in (LOCAL, WIFI, CELLULAR)}


class NetworkEmulator:
    def __init__(self, profile: NetProfile):
        self.profile = profile
        self.reset()

    def reset(self):
        self.virtual_time_s = 0.0
        self.round_trips = 0          # BLOCKING round trips (paper Table 1)
        self.async_trips = 0          # speculative commits: wire, no stall
        self.bytes_sent = 0
        self.bytes_received = 0
        self.collapsed_spins = 0      # poll spin trips folded into waits

    def round_trip(self, send_bytes: int = 64, recv_bytes: int = 64):
        """One synchronous request/response over the link."""
        self.round_trips += 1
        self.bytes_sent += send_bytes
        self.bytes_received += recv_bytes
        self.virtual_time_s += self.profile.rtt_s + \
            (send_bytes + recv_bytes) / self.profile.bw_bytes_s

    def async_trip(self, send_bytes: int = 256, recv_bytes: int = 64):
        """Asynchronous commit: consumes bandwidth but hides the RTT."""
        self.async_trips += 1
        self.bytes_sent += send_bytes
        self.bytes_received += recv_bytes
        self.virtual_time_s += (send_bytes + recv_bytes) / self.profile.bw_bytes_s

    def collapse_spins(self, n: int):
        """A compacted replay plan folded ``n`` poll spin trips into an
        enclosing completion wait.  The wait's own dispatch is billed
        normally by its commit; this only tracks the trips that did NOT
        cross the wire, so compacted-plan billing spans stay auditable
        against the naive plan (replay-pass ablation)."""
        self.collapsed_spins += int(n)

    def one_way(self, nbytes: int, direction: str = "send"):
        """One streamed transfer.  ``direction`` is from the client's point
        of view: 'send' = client->cloud (upload), 'recv' = cloud->client
        (download, e.g. a registry chunk fetch)."""
        if direction == "send":
            self.bytes_sent += nbytes
        elif direction == "recv":
            self.bytes_received += nbytes
        else:
            raise ValueError(f"direction must be send|recv, got {direction!r}")
        self.virtual_time_s += self.profile.rtt_s / 2 + \
            nbytes / self.profile.bw_bytes_s

    def one_way_recv(self, nbytes: int):
        self.one_way(nbytes, direction="recv")

    ACK_BYTES = 64

    def transfer(self, nbytes: int, chunk_size: int = 65536,
                 direction: str = "recv") -> int:
        """Chunked bulk transfer (registry fetch/publish billing): one
        blocking round trip to set the stream up, then a pipelined flow —
        bandwidth is paid for every byte, the RTT only once, and each chunk
        is acked asynchronously (``ACK_BYTES`` in the opposite direction).
        Returns the number of chunks billed."""
        if nbytes <= 0:
            return 0
        if chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        chunks = -(-nbytes // chunk_size)          # ceil division
        ack_bytes = self.ACK_BYTES * chunks
        if direction == "recv":
            self.bytes_received += nbytes
            self.bytes_sent += ack_bytes
        elif direction == "send":
            self.bytes_sent += nbytes
            self.bytes_received += ack_bytes
        else:
            raise ValueError(f"direction must be send|recv, got {direction!r}")
        self.round_trips += 1
        self.virtual_time_s += self.profile.rtt_s + \
            (nbytes + ack_bytes) / self.profile.bw_bytes_s
        return chunks

    def snapshot(self) -> dict:
        """Public counter snapshot: the ``checkpoint()`` shape (so
        ``async_trips``/``collapsed_spins`` are never dropped) plus a
        combined ``bytes`` total for quick display."""
        d = self.checkpoint()
        d["bytes"] = d["bytes_sent"] + d["bytes_received"]
        return d

    # -- span accounting ---------------------------------------------------
    # ``reset()`` is a global zeroing — unusable by nested consumers (a
    # session pass, registry billing) that need to measure their OWN span
    # of an emulator shared with everyone else.  checkpoint()/delta() are
    # non-destructive: take a mark, do work, subtract.
    def checkpoint(self) -> dict:
        """Full counter snapshot; pass to ``delta()`` to measure a span
        without clobbering global totals."""
        return {"time_s": self.virtual_time_s,
                "round_trips": self.round_trips,
                "async_trips": self.async_trips,
                "bytes_sent": self.bytes_sent,
                "bytes_received": self.bytes_received,
                "collapsed_spins": self.collapsed_spins}

    def delta(self, mark: dict) -> dict:
        """Counters accumulated since ``mark`` (a ``checkpoint()`` result).
        Leaves every global total untouched; spans may nest or overlap
        freely."""
        now = self.checkpoint()
        return {k: now[k] - mark.get(k, 0) for k in now}
