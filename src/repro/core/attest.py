"""Signing / fingerprints for recordings (paper §3.2: the cloud signs
recordings; the TEE replayer only accepts signed ones)."""
from __future__ import annotations

import hashlib
import hmac
import json


def fingerprint(*parts) -> str:
    h = hashlib.sha256()
    for p in parts:
        if isinstance(p, bytes):
            h.update(p)
        else:
            h.update(json.dumps(p, sort_keys=True, default=str).encode())
    return h.hexdigest()


def sign(payload: bytes, key: bytes) -> str:
    return hmac.new(key, payload, hashlib.sha256).hexdigest()


def verify(payload: bytes, signature: str, key: bytes) -> bool:
    return hmac.compare_digest(sign(payload, key), signature)


class TamperedRecordingError(Exception):
    pass


class UnverifiedRecordingError(ValueError):
    """A recording was about to be deserialized without HMAC verification
    and the caller did not explicitly opt in (``allow_unsigned=True``).
    Unsigned loads run ``pickle.loads`` on untrusted bytes — the exact
    attack the paper's signing step exists to prevent."""


class TopologyMismatchError(Exception):
    """Replay on hardware that does not match the recording (paper §2.4:
    recordings are only valid for the exact GPU/mesh they were made for)."""
