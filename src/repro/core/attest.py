"""Signing / fingerprints for recordings (paper §3.2: the cloud signs
recordings; the TEE replayer only accepts signed ones).

``repro.attest`` builds on these primitives: epoch-rotated signing keys
(``repro.attest.keys``), the registry transparency log
(``repro.attest.log``), and replay quotes (``repro.attest.quote``).  The
attest-level error taxonomy lives HERE so the offline verifier and the
registry can share it without importing each other.
"""
from __future__ import annotations

import hashlib
import hmac
import json


def _reject_unknown(obj):
    """Strict ``json.dumps`` default: refuse to fingerprint types the
    canonical encoding does not cover.  The old ``default=str`` fallback
    silently collapsed distinct objects with equal ``str()`` into ONE
    fingerprint — an identity collision, which for registry keys means
    two different recordings sharing a key."""
    raise TypeError(
        f"fingerprint: no canonical encoding for {type(obj).__name__!r} "
        f"({obj!r}); pass JSON-clean values (dict/list/str/int/float/bool/"
        "None) or raw bytes")


def canonical(part) -> bytes:
    """The canonical byte encoding one fingerprinted part hashes as:
    raw bytes pass through, everything else must be JSON-clean (strict —
    unknown types raise ``TypeError`` instead of str()-collapsing)."""
    if isinstance(part, bytes):
        return part
    return json.dumps(part, sort_keys=True,
                      default=_reject_unknown).encode()


def fingerprint(*parts) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(canonical(p))
    return h.hexdigest()


def sign(payload: bytes, key: bytes) -> str:
    return hmac.new(key, payload, hashlib.sha256).hexdigest()


def verify(payload: bytes, signature: str, key: bytes) -> bool:
    return hmac.compare_digest(sign(payload, key), signature)


class TamperedRecordingError(Exception):
    pass


class UnverifiedRecordingError(ValueError):
    """A recording was about to be deserialized without HMAC verification
    and the caller did not explicitly opt in (``allow_unsigned=True``).
    Unsigned loads run ``pickle.loads`` on untrusted bytes — the exact
    attack the paper's signing step exists to prevent."""


class TopologyMismatchError(Exception):
    """Replay on hardware that does not match the recording (paper §2.4:
    recordings are only valid for the exact GPU/mesh they were made for)."""


class AttestationError(TamperedRecordingError):
    """A transparency-log / attestation check failed.  Subclasses
    ``TamperedRecordingError`` so every existing catch-site that treats a
    failed integrity check as tampering keeps working unchanged."""


class SplitViewError(AttestationError):
    """The registry served bytes the transparency log does not vouch for:
    a silently swapped recording, a forked (split-view) log, or an
    unverifiable signed tree head.  Raised by clients BEFORE the fetched
    bytes can reach any ``pickle.loads``."""


class QuoteVerificationError(AttestationError):
    """A replay attestation quote failed offline verification (bad
    signature, unbound field, or a root the verifier does not trust)."""


class FutureEpochError(AttestationError):
    """A signature claims a key epoch that does not exist yet — either a
    forged epoch tag or a verifier whose key schedule is behind the
    signer's (which must surface, not silently fail verification)."""


class RotatedKeyError(ValueError):
    """A raw epoch key from an already-rotated-away epoch was offered
    where a current credential is required (e.g. ``Workspace(key=...)``
    with a stale ``EpochKey``)."""
