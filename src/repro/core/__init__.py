"""CODY core: record/replay of compiled execution plans + the paper's three
I/O optimizations (deferral, speculation, metastate-only sync), and the
ExecutionChannel transport seam the serving stack dispatches through."""
from repro.core.attest import (TamperedRecordingError, TopologyMismatchError,
                               UnverifiedRecordingError, fingerprint, sign,
                               verify)
from repro.core.channel import (ChannelCapabilityError, ExecutionChannel,
                                LiveChannel, NetemBilledChannel,
                                ReplayChannel)
from repro.core.deferral import (CommitQueue, Op, Symbol,
                                 SymbolReResolutionError,
                                 UnresolvedSymbolError)
from repro.core.metasync import DeltaSync, full_pack, is_metastate, merge, split
from repro.core.netem import (CELLULAR, LOCAL, PROFILES, WIFI, NetProfile,
                              NetworkEmulator)
from repro.core.recording import Recording
from repro.core.replay_passes import (REPLAY_PASS_NAMES, CommitCoalesce,
                                      DeadRegisterElim, PlanExecutor,
                                      PollCollapse, ReplayPlan, plan_for,
                                      replay_plan_report,
                                      resolve_replay_passes, verified_plan)
from repro.core.speculation import (HistorySpeculator, MispredictError,
                                    SpeculativeRunner)

__all__ = [
    "CommitQueue", "Op", "Symbol", "UnresolvedSymbolError",
    "SymbolReResolutionError", "Recording", "ExecutionChannel",
    "LiveChannel", "ReplayChannel", "NetemBilledChannel",
    "ChannelCapabilityError", "HistorySpeculator", "MispredictError",
    "SpeculativeRunner", "DeltaSync", "full_pack", "is_metastate", "merge",
    "split", "NetworkEmulator", "NetProfile", "PROFILES", "WIFI", "CELLULAR",
    "LOCAL",
    "fingerprint", "sign", "verify", "TamperedRecordingError",
    "TopologyMismatchError", "UnverifiedRecordingError",
    "REPLAY_PASS_NAMES", "ReplayPlan", "DeadRegisterElim", "PollCollapse",
    "CommitCoalesce", "PlanExecutor", "plan_for", "verified_plan",
    "replay_plan_report", "resolve_replay_passes",
]
