"""The replayer — CODY's in-TEE component.

Deliberately minimal: it imports NO model code, NO configs, NO training
machinery (tests assert this).  It loads a signed recording, verifies
(signature, format, topology), deserializes the executable, and executes it
on new inputs.  There is no tracing, no compilation, no Python model in the
TCB — the executable *is* the recorded interaction script.

Mirrors the paper's replayer obligations:
  * verify authenticity (cloud signature)            -> HMAC check
  * match recording to the exact hardware (§2.4)     -> topology fingerprint
  * reset/clean state around replay (§3.2)           -> fresh buffers, no
    state escapes except declared outputs (donation honored by XLA)
"""
from __future__ import annotations

import pickle
from typing import Any, Optional

import jax
from jax.experimental import serialize_executable as se

from repro.core.attest import (TamperedRecordingError, TopologyMismatchError,
                               fingerprint)
from repro.core.recording import Recording


def _topology_fingerprint() -> str:
    devs = jax.devices()
    return fingerprint(sorted(str(d.device_kind) for d in devs), len(devs))


class Replayer:
    def __init__(self, key: Optional[bytes] = None,
                 enforce_topology: bool = True):
        self._key = key
        self._enforce_topology = enforce_topology
        self._loaded = {}
        self.stats = {"loads": 0, "executions": 0, "rejected": 0}

    def load(self, path_or_bytes, name: Optional[str] = None):
        try:
            if isinstance(path_or_bytes, (bytes, bytearray)):
                rec = Recording.from_bytes(bytes(path_or_bytes), self._key)
            else:
                rec = Recording.load(path_or_bytes, self._key)
        except TamperedRecordingError:
            self.stats["rejected"] += 1
            raise
        if rec.manifest.get("exec_fingerprint") != fingerprint(rec.payload):
            self.stats["rejected"] += 1
            raise TamperedRecordingError("payload fingerprint mismatch")
        if self._enforce_topology and \
                rec.manifest["topology"] != _topology_fingerprint():
            self.stats["rejected"] += 1
            raise TopologyMismatchError(
                "recording was made for different hardware "
                f"({rec.manifest['topology'][:12]}... vs "
                f"{_topology_fingerprint()[:12]}...)")
        in_tree, out_tree = pickle.loads(rec.trees)
        exe = se.deserialize_and_load(rec.payload, in_tree, out_tree)
        nm = name or rec.manifest["name"]
        self._loaded[nm] = (exe, rec.manifest)
        self.stats["loads"] += 1
        return nm

    def manifest(self, name: str) -> dict:
        return self._loaded[name][1]

    def execute(self, name: str, *args) -> Any:
        """Run the recorded executable on new inputs.  No retracing ever."""
        exe, _man = self._loaded[name]
        self.stats["executions"] += 1
        return exe(*args)

    def __contains__(self, name: str) -> bool:
        return name in self._loaded
