"""The replayer — CODY's in-TEE component.

Deliberately minimal: it imports NO model code, NO configs, NO training
machinery (tests assert this).  It loads a signed recording, verifies
(signature, format, topology), deserializes the executable, and executes it
on new inputs.  There is no tracing, no compilation, no Python model in the
TCB — the executable *is* the recorded interaction script.

Mirrors the paper's replayer obligations:
  * verify authenticity (cloud signature)            -> HMAC check
  * match recording to the exact hardware (§2.4)     -> topology fingerprint
  * reset/clean state around replay (§3.2)           -> fresh buffers, no
    state escapes except declared outputs (donation honored by XLA)

Executables are cached by ``(name, input-avals)``: several recordings of
the same workload at different shapes (e.g. prefill shape buckets) can
share a logical name, and ``execute`` dispatches on the argument avals.
The aval signature is computed from the manifest ONCE at ``load``; the
per-call check is a tuple build + dict lookup, and a mismatch raises a
clear ``ReplayArgumentError`` instead of an XLA crash deep in the TEE
path.  ``warm`` runs a loaded executable once on zero inputs so the first
real block of the serving pipeline pays no allocation/cold-start cost.

The steady-state FAST PATH: once a sole-variant name has validated one
call, the resolved executable is pinned and every later ``execute`` for
that name dispatches directly — no ``jax.tree.leaves`` walk, no signature
tuple build, no variant-dict probing.  On the decode hot path (thousands
of identical-aval calls per stream) the signature build is ~half the
Python dispatch cost, so this is what lets replay dispatch match native
jit dispatch.  The pin is dropped the moment a second aval variant loads
under the name (multi-variant names always dispatch by signature —
correctness over speed).  ``stats['fast_hits']`` / ``stats['slow_
validations']`` count the two paths; the serving stack reads them through
``Workspace.report()``.
"""
from __future__ import annotations

import pickle
from typing import Any, Optional

import jax
import numpy as np
from jax.experimental import serialize_executable as se

from repro.core.attest import (TamperedRecordingError, TopologyMismatchError,
                               UnverifiedRecordingError, fingerprint)
from repro.core.recording import Recording


class ReplayArgumentError(TypeError):
    """Replay arguments do not match any recorded executable."""


def _topology_fingerprint() -> str:
    devs = jax.devices()
    return fingerprint(sorted(str(d.device_kind) for d in devs), len(devs))


def _aval_signature(leaves) -> tuple:
    return tuple((tuple(getattr(a, "shape", ())),
                  str(getattr(a, "dtype", ""))) for a in leaves)


class Replayer:
    def __init__(self, key: Optional[bytes] = None,
                 enforce_topology: bool = True,
                 allow_unsigned: bool = False):
        if key is None and not allow_unsigned:
            raise UnverifiedRecordingError(
                "Replayer without a signing key would pickle.loads "
                "unverified recordings; pass key=... or opt in with "
                "allow_unsigned=True")
        self._key = key
        self._allow_unsigned = allow_unsigned
        self._enforce_topology = enforce_topology
        self._loaded = {}   # name -> {aval_sig: (exe, manifest, in_tree)}
        self._fast = {}     # name -> exe, sole-variant names only, pinned
        #                     after the first validated execute()
        self.stats = {"loads": 0, "executions": 0, "rejected": 0,
                      "fast_hits": 0, "slow_validations": 0}

    def load(self, path_or_bytes, name: Optional[str] = None):
        try:
            if isinstance(path_or_bytes, (bytes, bytearray)):
                rec = Recording.from_bytes(
                    bytes(path_or_bytes), self._key,
                    allow_unsigned=self._allow_unsigned)
            else:
                rec = Recording.load(path_or_bytes, self._key,
                                     allow_unsigned=self._allow_unsigned)
        except TamperedRecordingError:
            self.stats["rejected"] += 1
            raise
        if rec.manifest.get("exec_fingerprint") != fingerprint(rec.payload):
            self.stats["rejected"] += 1
            raise TamperedRecordingError("payload fingerprint mismatch")
        if self._enforce_topology and \
                rec.manifest["topology"] != _topology_fingerprint():
            self.stats["rejected"] += 1
            raise TopologyMismatchError(
                "recording was made for different hardware "
                f"({rec.manifest['topology'][:12]}... vs "
                f"{_topology_fingerprint()[:12]}...)")
        in_tree, out_tree = pickle.loads(rec.trees)
        exe = se.deserialize_and_load(rec.payload, in_tree, out_tree)
        nm = name or rec.manifest["name"]
        # manifest aval check happens HERE, once: the signature is the
        # cache key, so every execute() validates by construction
        sig = tuple((tuple(i["shape"]), i["dtype"])
                    for i in rec.manifest["inputs"])
        self._loaded.setdefault(nm, {})[sig] = (exe, rec.manifest, in_tree)
        # any load under this name invalidates the fast-path pin: the name
        # may now be multi-variant, which must dispatch by signature
        self._fast.pop(nm, None)
        self.stats["loads"] += 1
        return nm

    def preload(self, items) -> list:
        """Load many recordings up front (paths, or (path, name) pairs) so
        the serving pipeline never loads mid-decode."""
        names = []
        for it in items:
            path, name = it if isinstance(it, tuple) else (it, None)
            names.append(self.load(path, name))
        return names

    def manifest(self, name: str, signature: Optional[tuple] = None) -> dict:
        """Manifest of a loaded recording.  With one variant loaded under
        ``name`` the answer is unambiguous; with several, the caller must
        say which (``signature`` = the aval signature used as the cache
        key) — silently returning *some* variant would leak dict ordering
        into replay behavior."""
        variants = self._loaded[name]
        if signature is not None:
            try:
                return variants[signature][1]
            except KeyError:
                raise ReplayArgumentError(
                    f"no variant of '{name}' with signature "
                    f"{self._describe(signature)}") from None
        if len(variants) != 1:
            raise ReplayArgumentError(
                f"'{name}' has {len(variants)} loaded variants; pass "
                "signature=... to pick one (or use manifests())")
        return next(iter(variants.values()))[1]

    def manifests(self, name: str) -> list:
        """Manifests of every loaded variant of ``name`` (load order)."""
        return [m for _exe, m, _tree in self._loaded[name].values()]

    def execute(self, name: str, *args) -> Any:
        """Run the recorded executable on new inputs.  No retracing ever;
        the aval lookup doubles as the shape/dtype validation — and once
        a sole-variant name has validated one call, later calls take the
        pinned fast path (no leaves walk, no signature build)."""
        exe = self._fast.get(name)
        if exe is not None:
            self.stats["fast_hits"] += 1
            self.stats["executions"] += 1
            return exe(*args)
        variants = self._loaded[name]
        sig = _aval_signature(jax.tree.leaves(args))
        hit = variants.get(sig)
        if hit is None:
            known = "\n  ".join(self._diff(sig, s) for s in variants)
            raise ReplayArgumentError(
                f"replay args for '{name}' match no recorded executable.\n"
                f"got:      {self._describe(sig)}\n"
                f"recorded: {known}")
        self.stats["slow_validations"] += 1
        self.stats["executions"] += 1
        if len(variants) == 1:
            self._fast[name] = hit[0]
        return hit[0](*args)

    def warm(self, name: str):
        """Execute every variant of ``name`` once on zero-filled inputs
        (outputs discarded) so real traffic hits warm buffers."""
        for sig, (exe, _man, in_tree) in self._loaded[name].items():
            leaves = [np.zeros(shape, dtype=np.dtype(dt))
                      for shape, dt in sig]
            args, kwargs = jax.tree.unflatten(in_tree, leaves)
            jax.block_until_ready(exe(*args, **kwargs))
            self.stats["executions"] += 1
        return name

    def quote(self, keys, name: str, *, head: dict,
              recording_key: Optional[str] = None) -> dict:
        """Replay attestation quote for a LOADED recording: binds the
        registry key, the verified executable fingerprint, and how many
        executions this replayer has served, against the signed tree head
        the recording was fetched under.  (Plan-level replays quote
        through ``PlanExecutor.quote`` instead, which additionally binds
        the compacted plan and the committed write frontier.)"""
        from repro.attest.quote import build_quote
        from repro.core.attest import fingerprint as fp
        manifests = self.manifests(name)
        exec_fp = manifests[0].get("exec_fingerprint", "")
        return build_quote(
            keys, recording_key=recording_key or name,
            exec_fingerprint=exec_fp, plan_fingerprint="",
            frontier_digest=fp({"executions": self.stats["executions"],
                                "loads": self.stats["loads"]}),
            head=head,
            annotations={"variants": len(self._loaded[name])})

    @staticmethod
    def _describe(sig) -> str:
        short = [f"{dt}{list(shape)}" for shape, dt in sig[:6]]
        more = f" ... +{len(sig) - 6} leaves" if len(sig) > 6 else ""
        return ", ".join(short) + more

    @staticmethod
    def _diff(got, want) -> str:
        """Describe a recorded signature, pointing at the first leaf that
        disagrees with ``got`` (the interesting one is often past any
        truncation)."""
        if len(got) != len(want):
            return (f"{Replayer._describe(want)}  "
                    f"[{len(want)} leaves, got {len(got)}]")
        for i, (g, w) in enumerate(zip(got, want)):
            if g != w:
                return (f"{Replayer._describe(want)}  [first mismatch at "
                        f"leaf {i}: got {g[1]}{list(g[0])}, recorded "
                        f"{w[1]}{list(w[0])}]")
        return Replayer._describe(want)

    def __contains__(self, name: str) -> bool:
        return name in self._loaded
