"""Replay-time interaction-plan compaction — the GPUReplay asymmetry.

Record needs the whole GPU software stack in the loop; replay does not.
A recorded interaction plan still carries everything the *driver* needed
while it was steering live hardware — boot-time register probing, power/
config readbacks it branched on, polling loops spun over the link — but
at replay time every one of those branches is already resolved: the
recording IS the resolution.  This module compacts the plan down to what
the replayed hardware actually consumes, mirroring the record-side pass
architecture (``repro.record.session``) with stackable, individually
ablatable passes in canonical order::

    naive plan ─► [dead] ─► [poll] ─► [coalesce] ─► PlanExecutor
                 dead-reg    spin       commit        dispatch over
                 access     collapse   coalescing     CommitQueue+netem
                 elim

  * ``dead``      — dead-register-access elimination: drop init probes and
                    pwr/cfg/irq reads whose readback is never consumed
                    downstream in the plan (the completion chain —
                    ``CloudDryrun.consumed_readbacks()`` — survives);
  * ``poll``      — poll-spin collapsing: a ``POLL_TRIPS``-trip spin
                    becomes ONE completion wait; the emulator records the
                    collapsed trips (``NetworkEmulator.collapse_spins``)
                    so compacted-plan billing spans stay auditable;
  * ``coalesce``  — commit coalescing: adjacent per-job doorbell/commit
                    segments fuse into single dispatches (the record
                    side's ``DeferralPass`` batching semantics, §4.1/§4.3
                    — enclosed polls are offloaded device-side).

Unlike a record session, a replay plan has NO post-job memory sync and no
cloud on the other end — the recording already holds the final state
(GPUReplay's ~50-KB footprint argument).  Compaction never touches the
recording's payload/trees/signature: a compacted plan stays bound to its
source recording by ``exec_fingerprint`` and ``verified_plan`` only
builds plans from recordings that verify under the caller's key.

Correctness invariant (tested): the committed WRITE sequence — the ops
that mutate the GPU — and the resolved values of every consumed readback
are identical between the naive and any compacted replay.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.deferral import CommitQueue
from repro.core.recording import Recording

REPLAY_PASS_NAMES = ("dead", "poll", "coalesce")

# ops per fused dispatch stay bounded: a real link MTU / command-ring depth
# would cap the batch, and one-giant-commit would hide the per-job structure
# the ablation reports.  4 jobs/dispatch mirrors the record side's
# speculation frontier granularity.
FUSE_JOBS = 4


def resolve_replay_passes(passes: Union[str, Sequence[str], None]) \
        -> Tuple[str, ...]:
    """Normalize a replay-pass spec — "all", "none"/"naive", comma string,
    or sequence — into canonical composition order."""
    if passes is None or passes == "all":
        return REPLAY_PASS_NAMES
    if passes == "none" or passes == "naive":
        return ()
    if isinstance(passes, str):
        passes = [p for p in passes.split(",") if p.strip()]
    names = {p.strip() for p in passes}
    unknown = names - set(REPLAY_PASS_NAMES)
    if unknown:
        raise ValueError(f"unknown replay passes {sorted(unknown)}; "
                         f"valid: {REPLAY_PASS_NAMES}")
    return tuple(p for p in REPLAY_PASS_NAMES if p in names)


@dataclasses.dataclass
class DispatchGroup:
    """One dispatch unit: the ops that ship to the device in one commit.
    The naive plan has one group per register access (1 blocking RTT
    each); coalescing fuses whole job segments into one group."""
    label: str
    ops: List[tuple]          # PlanOp (+ the compacted "wait" kind)


@dataclasses.dataclass
class ReplayPlan:
    """A recording's interaction plan in dispatchable form.

    ``source_fingerprint`` binds the plan to the recording it was derived
    from (``manifest["exec_fingerprint"]``); passes rewrite ``groups`` and
    append to ``passes``/``acct`` but never touch the binding.
    """
    name: str
    source_fingerprint: str
    jobs: int
    groups: List[DispatchGroup]
    passes: Tuple[str, ...] = ()
    acct: Dict[str, dict] = dataclasses.field(default_factory=dict)

    @property
    def n_ops(self) -> int:
        return sum(len(g.ops) for g in self.groups)

    def op_sites(self, kind: Optional[str] = None) -> List[str]:
        return [op[1] for g in self.groups for op in g.ops
                if kind is None or op[0] == kind]


# ------------------------------------------------------------- the passes --
class DeadRegisterElim:
    """Drop reads whose readback the downstream plan never consumes.

    At record time those reads were control-dependency commit points (the
    live driver branched on them); at replay time the branch outcomes are
    baked into the plan, so only the completion chain's readbacks (poll /
    flush id / job status — ``consumed``) still carry information.  Writes
    and polls always survive: they are what drives the hardware.
    """

    name = "dead"

    def __init__(self, consumed):
        self.consumed = frozenset(consumed)

    def apply(self, plan: ReplayPlan) -> ReplayPlan:
        dropped = 0
        groups = []
        for g in plan.groups:
            kept = [op for op in g.ops
                    if op[0] != "read" or op[1] in self.consumed]
            dropped += len(g.ops) - len(kept)
            if kept:
                groups.append(DispatchGroup(g.label, kept))
        plan.groups = groups
        plan.acct[self.name] = {"reads_dropped": dropped,
                                "ops_remaining": plan.n_ops}
        return plan


class PollCollapse:
    """Fold each ``POLL_TRIPS``-trip spin into ONE completion wait.

    The naive replay spins a poll over the link exactly like the record
    side's ``WireLink`` (one blocking round trip per trip).  The collapsed
    ``wait`` op ships once and blocks once; its payload remembers how many
    spin trips it replaced so the executor can report the collapse to the
    emulator's billing span.
    """

    name = "poll"

    def __init__(self, poll_trips: int):
        self.poll_trips = poll_trips

    def apply(self, plan: ReplayPlan) -> ReplayPlan:
        collapsed = 0
        for g in plan.groups:
            for i, op in enumerate(g.ops):
                if op[0] == "poll":
                    g.ops[i] = ("wait", op[1], self.poll_trips, op[3])
                    collapsed += 1
        plan.acct[self.name] = {
            "polls_collapsed": collapsed,
            "spins_collapsed": collapsed * (self.poll_trips - 1)}
        return plan


class CommitCoalesce:
    """Fuse adjacent per-job dispatch groups into single commits.

    Reuses the record side's ``DeferralPass`` batching semantics: ops queue
    in program order on one ``CommitQueue`` and ship together; polls inside
    a fused batch execute as offloaded device-side loops (§4.3).  With the
    cdep branches pre-resolved by the recording there is nothing left to
    commit *for* mid-job, so the dispatch boundary becomes the fused-job
    boundary: ``fuse_jobs`` adjacent job segments per commit.
    """

    name = "coalesce"

    def __init__(self, fuse_jobs: int = FUSE_JOBS):
        self.fuse_jobs = max(1, fuse_jobs)

    def apply(self, plan: ReplayPlan) -> ReplayPlan:
        before = len(plan.groups)
        # merge groups back into their originating segments, in order
        segs: List[DispatchGroup] = []
        for g in plan.groups:
            if segs and segs[-1].label == g.label:
                segs[-1].ops.extend(g.ops)
            else:
                segs.append(DispatchGroup(g.label, list(g.ops)))
        fused: List[DispatchGroup] = []
        run: List[DispatchGroup] = []

        def flush_run():
            if run:
                fused.append(DispatchGroup(
                    run[0].label if len(run) == 1 else
                    f"{run[0].label}..{run[-1].label}",
                    [op for s in run for op in s.ops]))
                run.clear()

        for seg in segs:
            if seg.label.startswith("job"):
                run.append(seg)
                if len(run) == self.fuse_jobs:
                    flush_run()
            else:
                flush_run()
                fused.append(seg)
        flush_run()
        plan.groups = fused
        plan.acct[self.name] = {"dispatches_before": before,
                                "dispatches_after": len(fused),
                                "fuse_jobs": self.fuse_jobs}
        return plan


# -------------------------------------------------------- plan construction --
def plan_for(rec: Recording, passes: Union[str, Sequence[str], None] = "all",
             *, jobs: Optional[int] = None, cloud=None,
             fuse_jobs: int = FUSE_JOBS) -> ReplayPlan:
    """Materialize ``rec``'s interaction plan and compact it with the
    requested passes (canonical order).  ``jobs`` pins the GPU job count
    exactly as on the record side, so replay and record ablations are
    comparable for one artifact."""
    from repro.record.cloud import CloudDryrun
    from repro.record.device import POLL_TRIPS
    if cloud is None:
        cloud = CloudDryrun(jobs=jobs)
    groups = [DispatchGroup(seg, [op])
              for seg, ops in cloud.interaction_plan(rec) for op in ops]
    plan = ReplayPlan(name=rec.manifest.get("name", ""),
                      source_fingerprint=rec.manifest.get(
                          "exec_fingerprint", ""),
                      jobs=cloud.plan_jobs(rec), groups=groups)
    stack = resolve_replay_passes(passes)
    built = {"dead": lambda: DeadRegisterElim(cloud.consumed_readbacks()),
             "poll": lambda: PollCollapse(POLL_TRIPS),
             "coalesce": lambda: CommitCoalesce(fuse_jobs)}
    for name in stack:
        plan = built[name]().apply(plan)
    plan.passes = stack
    return plan


def verified_plan(blob: bytes, key: bytes,
                  passes: Union[str, Sequence[str], None] = "all", *,
                  jobs: Optional[int] = None,
                  fuse_jobs: int = FUSE_JOBS) -> Tuple[ReplayPlan, Recording]:
    """Verify signed recording bytes under ``key`` (HMAC before anything
    else — tampered bytes never reach plan construction), then compact.
    Returns ``(plan, recording)``; the plan's ``source_fingerprint`` is the
    verified recording's executable fingerprint."""
    from repro.core.attest import TamperedRecordingError, fingerprint
    rec = Recording.from_bytes(blob, key)
    if rec.manifest.get("exec_fingerprint") != fingerprint(rec.payload):
        raise TamperedRecordingError("payload fingerprint mismatch")
    return plan_for(rec, passes, jobs=jobs, fuse_jobs=fuse_jobs), rec


# ------------------------------------------------------------ the executor --
class PlanExecutor:
    """Plays a (compacted) replay plan through a ``CommitQueue`` ->
    ``DeviceProxy`` over an emulated link — the replay-side analogue of the
    record session's wire protocol.

    Dispatch semantics (what the ablation measures):

      * a single-op group ships as its own blocking round trip — the naive
        base, one RTT per register access, exactly ``WireLink``;
      * an UNCOLLAPSED standalone poll spins ``POLL_TRIPS`` blocking round
        trips (read + commit per trip), again mirroring ``WireLink``;
      * a collapsed ``wait`` ships once, blocks once, and reports the spin
        trips it replaced to ``NetworkEmulator.collapse_spins``;
      * a fused multi-op group queues everything and commits ONCE; polls
        and waits inside it run as offloaded device-side loops.

    Single-use, like ``RecordingSession``: device state and the commit log
    belong to one replay.
    """

    def __init__(self, netem=None, device=None, tracer=None):
        from repro.obs.trace import NULL
        from repro.record.device import POLL_TRIPS, DeviceProxy
        self.device = device if device is not None else DeviceProxy()
        self.netem = netem
        self.tracer = tracer if tracer is not None else NULL
        self.poll_trips = POLL_TRIPS
        self.q = CommitQueue(self.device.channel, netem=netem,
                             name="replay-plan")
        self._ran = False
        self._plan: Optional[ReplayPlan] = None

    def run(self, plan: ReplayPlan) -> dict:
        from repro.obs.trace import traced
        if self._ran:
            raise RuntimeError("PlanExecutor is single-use: build a new "
                               "executor per replayed plan")
        self._ran = True
        self._plan = plan
        mark = self.netem.checkpoint() if self.netem else None
        q = self.q
        tr = self.tracer
        with tr.clock_scope(self.netem):
            for i, g in enumerate(plan.groups):
                if len(g.ops) == 1 and g.ops[0][0] == "poll":
                    # naive spin, one blocking round trip per trip: warm-up
                    # trips re-read the poll site (not-ready), the final trip
                    # is the dispatch that resolves the completion value
                    with traced(tr, "replay.poll_spin", "replay",
                                group=i, site=g.ops[0][1],
                                trips=self.poll_trips):
                        for _ in range(self.poll_trips - 1):
                            q.read(g.ops[0][1])
                            q.commit()
                        q.poll(g.ops[0][1])
                        q.commit()
                    continue
                with traced(tr, "replay.dispatch", "replay",
                            group=i, ops=len(g.ops)):
                    for kind, site, payload, _cdep in g.ops:
                        if kind == "write":
                            q.write(site, payload)
                        elif kind == "read":
                            q.read(site)
                        elif kind in ("poll", "wait"):
                            q.poll(site)  # offloaded device-side loop
                            if kind == "wait" and self.netem is not None:
                                self.netem.collapse_spins(payload - 1)
                                if tr:
                                    tr.instant("replay.collapsed_poll",
                                               "replay", group=i, site=site,
                                               spins=payload - 1)
                        else:
                            raise ValueError(
                                f"unknown replay op kind {kind!r}")
                    q.commit()
        totals = self.netem.delta(mark) if mark is not None else {}
        return self._report(plan, totals)

    # ---------------------------------------------------------- attestation --
    def quote(self, keys, *, recording_key: str, head: dict) -> dict:
        """Emit a replay attestation quote for the plan this executor
        ran: binds the recording key, the source executable fingerprint,
        the compacted plan's identity, the committed write frontier, and
        the signed tree head the recording was fetched under.  Offline-
        verifiable via ``repro.attest.verifier.verify_quote``."""
        from repro.attest.quote import (build_quote, frontier_digest_of,
                                        plan_fingerprint_of)
        if not self._ran or self._plan is None:
            raise RuntimeError("quote() before run(): a quote attests an "
                               "executed replay, not an intention")
        return build_quote(
            keys, recording_key=recording_key,
            exec_fingerprint=self._plan.source_fingerprint,
            plan_fingerprint=plan_fingerprint_of(self._plan),
            frontier_digest=frontier_digest_of(self.write_log()),
            head=head,
            annotations={"passes": list(self._plan.passes),
                         "dispatches": len(self._plan.groups),
                         "writes": len(self.write_log())})

    # ----------------------------------------------------------- inspection --
    def write_log(self) -> List[tuple]:
        """Committed ``(site, payload)`` write sequence — the plan-level
        bit-exactness witness: compaction must never change it."""
        return [(op.site, op.payload) for op in self.q.log
                if op.kind == "write"]

    def readback_log(self, sites=None) -> List[tuple]:
        """Resolved ``(site, value)`` readbacks, optionally filtered to the
        consumed set — the raw committed order, spins included."""
        return [(op.site, op.symbol.value) for op in self.q.log
                if op.symbol is not None and op.symbol.resolved
                and (sites is None or op.site in sites)]

    def consumed_log(self, sites) -> List[tuple]:
        """The OTHER bit-exactness witness: the consumed completion values.
        A naive spin's warm-up trips re-read the poll site (each readback
        is "not ready yet"); only the final trip's value is what the plan
        consumes — so runs of consecutive same-site entries collapse to
        their last value.  Identical across pass stacks by construction,
        and the tests pin it."""
        raw = self.readback_log(sites)
        out: List[tuple] = []
        for site, value in raw:
            if out and out[-1][0] == site:
                out[-1] = (site, value)
            else:
                out.append((site, value))
        return out

    def _report(self, plan: ReplayPlan, totals: dict) -> dict:
        return {
            "net": self.netem.profile.name if self.netem else "in-process",
            "passes": list(plan.passes),
            "virtual_time_s": round(float(totals.get("time_s", 0.0)), 6),
            "blocking_round_trips": int(totals.get("round_trips", 0)),
            "async_round_trips": int(totals.get("async_trips", 0)),
            "bytes_sent": int(totals.get("bytes_sent", 0)),
            "bytes_received": int(totals.get("bytes_received", 0)),
            "collapsed_spins": int(totals.get("collapsed_spins", 0)),
            "dispatches": len(plan.groups),
            "plan_ops": plan.n_ops,
            "ops_executed": len(self.device.exec_log),
            "writes": len(self.write_log()),
            "jobs": plan.jobs,
            "per_pass": dict(plan.acct),
        }


def replay_plan_report(rec: Recording, passes="all", *, netem=None,
                       jobs: Optional[int] = None,
                       fuse_jobs: int = FUSE_JOBS) -> dict:
    """One-call convenience: compact ``rec``'s plan and execute it over
    ``netem`` (None = unbilled in-process), returning the executor report."""
    plan = plan_for(rec, passes, jobs=jobs, fuse_jobs=fuse_jobs)
    return PlanExecutor(netem=netem).run(plan)


__all__ = ["REPLAY_PASS_NAMES", "FUSE_JOBS", "resolve_replay_passes",
           "ReplayPlan", "DispatchGroup", "DeadRegisterElim", "PollCollapse",
           "CommitCoalesce", "plan_for", "verified_plan", "PlanExecutor",
           "replay_plan_report"]
