"""Register-access deferral (paper §4.1) on the host<->accelerator channel.

The paper's DriverShim queues GPU register accesses in program order,
represents unread values as *symbols* so the driver keeps executing, and
commits the queue in one network round trip when a value is actually needed
(control dependency), at externalization points, or at explicit barriers.

Here the "registers" are host<->device interactions of a serving/training
runtime: dispatches (writes) and readbacks (reads: done-flags, token values,
metrics).  ``CommitQueue`` preserves program order per stream, coalesces
round trips, and supports symbolic reads exactly like the paper.

This module is runtime-agnostic: the channel is anything callable that
executes one ``Op`` at a time in program order — a real device loop, the
NetworkEmulator-backed fake used by the paper-reproduction benchmarks, or
a serving stream's executor (which turns ops into ``ExecutionChannel``
step dispatches, see ``repro.core.channel``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, List, Optional

_ids = itertools.count()


class Symbol:
    """A deferred read value (paper: symbolic register value).

    ``sid`` identifies the symbol within its queue/session.  Symbols made
    through a ``CommitQueue`` get ids from that queue's own counter, so op
    logs are deterministic per session; a bare ``Symbol(site)`` falls back
    to a module-global counter (standalone use only — ids from that
    counter leak across sessions and are NOT reproducible)."""
    __slots__ = ("sid", "site", "_value", "resolved")

    def __init__(self, site: str, sid: Optional[int] = None):
        self.sid = next(_ids) if sid is None else sid
        self.site = site
        self._value = None
        self.resolved = False

    @property
    def value(self):
        if not self.resolved:
            raise UnresolvedSymbolError(f"symbol {self.sid} @ {self.site}")
        return self._value

    def resolve(self, v):
        if self.resolved:
            # a second resolution would silently rewrite history the
            # speculation/validation machinery already acted on
            raise SymbolReResolutionError(
                f"symbol {self.sid} @ {self.site} already resolved")
        self._value = v
        self.resolved = True

    def __repr__(self):
        return f"S{self.sid}({self._value if self.resolved else '?'})"


class UnresolvedSymbolError(Exception):
    pass


class SymbolReResolutionError(RuntimeError):
    """A deferred read was resolved twice (program-order violation)."""


@dataclasses.dataclass
class Op:
    kind: str                  # "read" | "write" | "poll"
    site: str                  # program location (paper: driver source loc)
    payload: Any = None        # may contain Symbols (data dependencies)
    symbol: Optional[Symbol] = None   # for reads


def _resolve_payload(p):
    if isinstance(p, Symbol):
        return p.value
    if isinstance(p, (list, tuple)):
        return type(p)(_resolve_payload(x) for x in p)
    if isinstance(p, dict):
        return {k: _resolve_payload(v) for k, v in p.items()}
    return p


class CommitQueue:
    """Per-stream deferred interaction queue (program order preserved).

    ``channel(op) -> result_or_None`` executes ONE interaction on the
    device side; a commit ships the whole queue in a single round trip and
    the client executes ops in order, resolving intra-batch symbolic
    references as it goes (the paper ships symbols to the client the same
    way).  ``netem`` (optional) accounts the virtual network cost; the log
    of committed interactions *is* the recording trace.
    """

    def __init__(self, channel: Callable[[Op], Any],
                 netem=None, name: str = "stream0"):
        self.channel = channel
        self.netem = netem
        self.name = name
        # symbol ids are scoped to THIS queue: two freshly built sessions
        # replaying the same program produce identical op logs (a module-
        # global counter leaked ids across sessions/tests)
        self._sids = itertools.count()
        self.queue: List[Op] = []
        self.log: List[Op] = []            # committed interaction log
        self.commits = 0                   # blocking commits (1 RTT each)
        self.async_commits = 0             # shipped without stalling
        self.deferred_total = 0

    # -- deferral API (paper fig. 5b) --
    def write(self, site: str, payload=None):
        self.queue.append(Op("write", site, payload))
        self.deferred_total += 1

    def read(self, site: str) -> Symbol:
        s = Symbol(site, sid=next(self._sids))
        self.queue.append(Op("read", site, symbol=s))
        self.deferred_total += 1
        return s

    def poll(self, site: str, predicate_site: str = "") -> Symbol:
        """Offloaded polling loop (§4.3): executes device-side in the same
        commit; the read value is the loop's final state / trip count."""
        s = Symbol(site, sid=next(self._sids))
        self.queue.append(Op("poll", site, payload=predicate_site, symbol=s))
        self.deferred_total += 1
        return s

    def need(self, symbol: Symbol):
        """Control dependency on a deferred read -> synchronous commit."""
        if not symbol.resolved:
            self.commit()
        return symbol.value

    # -- commit --
    def execute_ops(self, ops: List[Op]) -> List[Any]:
        """Client-side in-order execution; resolves symbols as it goes so
        later ops in the same batch may reference earlier reads."""
        results = []
        for op in ops:
            op.payload = _resolve_payload(op.payload)
            res = self.channel(op)
            if op.symbol is not None:
                op.symbol.resolve(res)
                results.append(res)
        return results

    def commit(self, approx_bytes: int = 256) -> List[Any]:
        if not self.queue:
            return []
        ops = self.queue
        self.queue = []
        results = self.execute_ops(ops)
        self.log.extend(ops)
        self.commits += 1
        if self.netem is not None:
            send, recv = _wire_bytes(ops, results)
            self.netem.round_trip(send_bytes=max(send, approx_bytes),
                                  recv_bytes=recv)
        return results

    def commit_async(self, approx_bytes: int = 256) -> List[Any]:
        """Ship the queue WITHOUT a blocking round trip (paper fig. 5c).

        The client executes the batch now; read symbols resolve to whatever
        the channel returns — for the serving engine that is an in-flight
        device future, so the host keeps running and only materializes the
        value at the commit frontier.  Wire bytes are accounted with the
        same op/byte math as ``commit`` but as a non-blocking trip, so
        speculative and synchronous shipping can never drift apart in
        netem accounting."""
        if not self.queue:
            return []
        ops = self.queue
        self.queue = []
        results = self.execute_ops(ops)
        self.log.extend(ops)
        self.async_commits += 1
        if self.netem is not None:
            send, recv = _wire_bytes(ops, results)
            self.netem.async_trip(send_bytes=max(send, approx_bytes),
                                  recv_bytes=recv)
        return results

    def flush(self):
        return self.commit()


def _wire_bytes(ops: List[Op], results: List[Any]):
    """(send, recv) bytes for one shipped batch — the single source of
    truth for commit/commit_async netem accounting."""
    send = sum(64 + _payload_bytes(o.payload) for o in ops)
    recv = 64 + 8 * len(results)
    return send, recv


def _payload_bytes(p) -> int:
    if p is None:
        return 0
    if isinstance(p, (bytes, bytearray)):
        return len(p)
    if isinstance(p, (list, tuple)):
        return sum(_payload_bytes(x) for x in p)
    if isinstance(p, dict):
        return sum(_payload_bytes(v) for v in p.values())
    if hasattr(p, "nbytes"):
        return int(p.nbytes)
    return 8
