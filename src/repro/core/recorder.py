"""The recorder — CODY's "cloud dryrun service" on the JAX AOT path.

``compile_artifact()`` exercises the full framework stack (model code,
sharding rules, XLA) exactly once per (workload x shape x mesh): it lowers
and compiles the step function against abstract inputs (ShapeDtypeStructs —
the paper's dryrun needs no real data, §5 "metastate only"), serializes the
executable, and builds the signable Recording.  Replay needs none of this
machinery.

``record()`` is the paper's full record phase: it runs the compile through
an in-process degenerate ``repro.record.RecordingSession`` (device proxy and
cloud dryrun co-located, all three optimization passes on, nothing billed) —
same Recording output as ``compile_artifact``, plus the session fields
(``record_virtual_s`` and per-pass counters, zero for local records).  The
distributed record phase — device and cloud on opposite ends of an emulated
link — lives in ``repro.record`` and produces the same artifact with real
wire accounting.
"""
from __future__ import annotations

import pickle
import time
from typing import Any, Optional, Sequence

import jax
from jax.experimental import serialize_executable as se

from repro.compat import cost_analysis, set_mesh
from repro.core.attest import fingerprint
from repro.core.recording import Recording


def topology_fingerprint() -> str:
    devs = jax.devices()
    return fingerprint(sorted(str(d.device_kind) for d in devs), len(devs))


def mesh_descriptor(mesh) -> dict:
    return {"shape": list(mesh.devices.shape), "axes": list(mesh.axis_names)}


def compile_artifact(name: str, fn, args_abstract: Sequence[Any], *,
                     mesh=None, in_shardings=None, out_shardings=None,
                     donate_argnums=(), config_fingerprint: str = "",
                     static_meta: Optional[dict] = None) -> Recording:
    """Lower + compile + serialize ``fn`` into a signed-ready Recording."""
    t0 = time.time()
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    jitted = jax.jit(fn, donate_argnums=donate_argnums, **kw)
    if mesh is not None:
        with set_mesh(mesh):
            lowered = jitted.lower(*args_abstract)
            compiled = lowered.compile()
    else:
        lowered = jitted.lower(*args_abstract)
        compiled = lowered.compile()
    payload, in_tree, out_tree = se.serialize(compiled)
    trees = pickle.dumps((in_tree, out_tree))

    flat, _ = jax.tree.flatten(args_abstract)
    manifest = {
        "name": name,
        "created_s": time.time(),
        "record_wall_s": time.time() - t0,
        "jax_version": jax.__version__,
        "topology": topology_fingerprint(),
        "mesh": mesh_descriptor(mesh) if mesh is not None else None,
        "config_fingerprint": config_fingerprint,
        "donate": list(donate_argnums),
        "inputs": [{"shape": list(getattr(a, "shape", ())),
                    "dtype": str(getattr(a, "dtype", ""))} for a in flat],
        "cost": {k: float(v) for k, v in cost_analysis(compiled).items()
                 if isinstance(v, (int, float))},
        "memory": {
            "arg_bytes": compiled.memory_analysis().argument_size_in_bytes,
            "temp_bytes": compiled.memory_analysis().temp_size_in_bytes,
            "out_bytes": compiled.memory_analysis().output_size_in_bytes,
        },
        "static": static_meta or {},
    }
    manifest["exec_fingerprint"] = fingerprint(payload)
    return Recording(manifest=manifest, payload=payload, trees=trees)


def record(name: str, fn, args_abstract: Sequence[Any], *,
           mesh=None, in_shardings=None, out_shardings=None,
           donate_argnums=(), config_fingerprint: str = "",
           static_meta: Optional[dict] = None, session=None) -> Recording:
    """Record ``fn`` through a ``RecordingSession`` (the CODY two-party
    record phase).  Without ``session`` this is the in-process degenerate
    session — LOCAL co-located device+cloud, all passes on, nothing billed
    — whose Recording is the same artifact ``compile_artifact`` builds.
    Pass a session built over a real ``NetProfile`` (see
    ``repro.record.RecordingSession.for_profile``) to bill the distributed
    record protocol into its emulator and into the manifest."""
    # lazy import: repro.record composes over this module's compile path
    from repro.record import RecordingSession
    sess = session if session is not None else RecordingSession.local()
    return sess.record(name, fn, args_abstract, mesh=mesh,
                       in_shardings=in_shardings,
                       out_shardings=out_shardings,
                       donate_argnums=donate_argnums,
                       config_fingerprint=config_fingerprint,
                       static_meta=static_meta)
