"""End-to-end training driver (runs on this host's devices).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together: data pipeline -> recorded train step (CODY recorder: the
step is lowered+compiled once, AOT) -> AdamW -> async checkpoints ->
elastic restore (resume on a different device count just works).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_shrink
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.models import model as M
from repro.runtime.checkpoint import CheckpointStore
from repro.runtime.elastic import reshard_state
from repro.sharding import rules_for
from repro.training import steps as ST
from repro.training.grad_compress import make_ef_int8_transform
from repro.training.optimizer import AdamWConfig, init_opt_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_shrink(cfg)
    mesh = make_host_mesh(model=1)
    rules = rules_for("train", mesh.axis_names)

    opt = AdamWConfig(lr=args.lr, warmup_steps=10, decay_steps=args.steps)
    gt = make_ef_int8_transform() if args.grad_compress else None
    train_step = ST.make_train_step(cfg, rules, opt, remat=args.remat,
                                    grad_transform=gt)

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    state = init_opt_state(params)
    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    data = SyntheticLM(cfg.vocab_size, args.batch, args.seq)
    start_step = 0
    if store and args.resume and store.latest_step() is not None:
        state_np, manifest = store.restore(state)
        state = reshard_state(state_np, ST.train_state_axes(cfg), mesh)
        data.restore(manifest["extra"])
        start_step = manifest["step"]
        print(f"resumed from step {start_step} on {len(jax.devices())} devices")

    with set_mesh(mesh):
        jitted = jax.jit(train_step, donate_argnums=(0,))
        loader = Prefetcher(data)
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
            state, metrics = jitted(state, batch)
            if (step + 1) % args.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                print(f"step {step+1:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                      f"({(time.time()-t0)/args.log_every*1000:.0f} ms/step)")
                t0 = time.time()
            if store and (step + 1) % args.ckpt_every == 0:
                store.async_save(state, step + 1, extra_meta=data.meta())
        if store:
            store.wait()
            store.save(state, args.steps, extra_meta=data.meta())
        loader.close()
    final = float(metrics["loss"])
    print(f"done: final loss {final:.4f}")
    return final


if __name__ == "__main__":
    main()
