"""Attested-replay lifecycle demo + offline quote verification.

Demo (records, publishes through the transparency log, replays with
proof verification, emits a signed quote bundle)::

    python -m repro.launch.attest --arch qwen2.5-3b --net wifi \
        --out /tmp/attest_quote.json

Offline verification of a previously emitted bundle — this path imports
ONLY ``repro.attest`` (no model, registry, or serving code), i.e. what a
remote verifier would run::

    python -m repro.launch.attest --verify /tmp/attest_quote.json \
        --key cody-demo-key

``--rotate`` advances the key-schedule epoch after publishing, showing
that heads/quotes signed in older epochs stay verifiable.

This module is CLI-only: the attestation layer itself is ``repro.attest``.
"""
from __future__ import annotations

import argparse
import json


def _verify(path: str, key: bytes) -> int:
    # the offline half: repro.attest only — nothing a replica controls
    from repro.attest import KeySchedule, verify_quote
    with open(path) as f:
        bundle = json.load(f)
    keys = KeySchedule(key)
    for _ in range(int(bundle.get("epoch", 0))):
        keys.rotate()
    report = verify_quote(bundle["quote"], head=bundle["head"], keys=keys,
                          leaf=bundle.get("leaf"),
                          proof=bundle.get("path"),
                          leaf_index=bundle.get("index"))
    print(f"quote VERIFIED: key={report['recording_key']} "
          f"epoch={report['epoch']} log_size={report['log_size']} "
          f"root={report['root'][:16]}... "
          f"inclusion={'checked' if report['inclusion_checked'] else 'skipped'}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="attested replay: transparency-log publish, "
                    "proof-verified fetch, signed replay quote")
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--net", default="wifi")
    ap.add_argument("--jobs", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--key", default="cody-demo-key")
    ap.add_argument("--rotate", action="store_true",
                    help="rotate the signing epoch after publish (older-"
                         "epoch signatures must still verify)")
    ap.add_argument("--out", default="/tmp/attest_quote.json",
                    help="quote-bundle JSON output path")
    ap.add_argument("--verify", default="",
                    help="offline-verify a quote bundle instead of "
                         "running the demo")
    args = ap.parse_args(argv)
    key = args.key.encode()

    if args.verify:
        return _verify(args.verify, key)

    from repro.api import Workspace
    ws = Workspace(registry=":memory:", key=key, net=args.net)
    wl = ws.workload(args.arch, cache_len=args.cache_len,
                     block_k=args.block_k, batch=2, seq=args.seq)

    print(f"== record + publish (epoch {ws.keys.epoch}) ==")
    rec = wl.record("prefill", jobs=args.jobs)
    pub = wl.publish(rec)
    print(f"   log_index={pub['log_index']} log_size={pub['log_size']} "
          f"root={pub['root'][:16]}...")

    if args.rotate:
        print(f"== rotate epoch -> {ws.rotate_epoch()} ==")

    print("== attested replay (proof-verified fetch) ==")
    rep, quote, bundle = wl.attested_replay("prefill", jobs=args.jobs)
    att = ws.report()["attest"]
    print(f"   virtual {rep['virtual_time_s']:.3f}s, "
          f"{rep['dispatches']} dispatches; proofs_verified="
          f"{att['proofs_verified']} proof_bytes={att['proof_bytes']}")

    out = {"quote": quote, "head": bundle["head"], "leaf": bundle["leaf"],
           "index": bundle["index"], "path": bundle["path"],
           "epoch": ws.keys.epoch}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"quote bundle: {args.out}")

    print("== offline verification ==")
    return _verify(args.out, key)


if __name__ == "__main__":
    raise SystemExit(main())
