"""Recording-campaign CLI — a thin shim over ``Workspace.campaign``.

Fans a key's shape variants out across a device pool and publishes each
finished variant into the registry through the multi-variant lease:

    python -m repro.launch.fanout --arch qwen2.5-3b --devices 4 \
        --seqs 8,16,32,64 --registry /tmp/reg --key secret --net wifi
    python -m repro.launch.fanout --devices 4 --net wifi,cellular \
        --no-share-history     # cold-per-session baseline

Prints the per-device assignment table and the campaign accounting:
makespan vs the sum of per-record times, speculation hit rates per
device (shared history warms later devices), skips for already-published
variants.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.api import Workspace
from repro.core import PROFILES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--net", default="wifi",
                    help="comma list of link profiles, round-robin over "
                         f"devices ({'|'.join(sorted(PROFILES))})")
    ap.add_argument("--seqs", default="8,16,32,64",
                    help="prefill seq buckets to record (decode rides "
                         "along once)")
    ap.add_argument("--kinds", default="prefill,decode")
    ap.add_argument("--registry", default=None,
                    help="registry root (default: in-memory, print-only)")
    ap.add_argument("--key", default="cody-demo-key")
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill-batch", type=int, default=1)
    ap.add_argument("--jobs", type=int, default=None,
                    help="pin per-session job count (determinism across "
                         "recompiles)")
    ap.add_argument("--passes", default="all")
    ap.add_argument("--hw-class", default="edge-gpu")
    ap.add_argument("--no-share-history", action="store_true",
                    help="cold speculator per session (the serial "
                         "baseline's behavior)")
    args = ap.parse_args(argv)

    registry = args.registry if args.registry else ":memory:"
    if args.registry:
        os.makedirs(args.registry, exist_ok=True)
    nets = [n.strip() for n in args.net.split(",") if n.strip()]
    ws = Workspace(registry=registry, key=args.key.encode(), net=nets[0],
                   record_passes=args.passes)
    wl = ws.workload(args.arch, smoke=args.smoke, cache_len=args.cache_len,
                     block_k=args.block_k, batch=args.batch,
                     prefill_batch=args.prefill_batch,
                     seq=int(args.seqs.split(",")[0]))
    seqs = [int(s) for s in args.seqs.split(",") if s.strip()]
    kinds = tuple(k.strip() for k in args.kinds.split(",") if k.strip())
    items = wl.variants(seqs=seqs, kinds=kinds)
    campaign = ws.campaign(items, devices=args.devices, nets=nets,
                           hw_class=args.hw_class,
                           share_history=not args.no_share_history,
                           jobs=args.jobs, name=f"fanout-{args.arch}")
    print(f"campaign: {len(items)} variants over {args.devices} devices "
          f"({'+'.join(nets)}), shared history="
          f"{not args.no_share_history}")
    campaign.run()
    s = campaign.stats()
    for d in s["per_device"]:
        spec = d["spec"]
        hr = (spec["hit"] / spec["predict"]) if spec["predict"] else 0.0
        print(f"  {d['name']}[{d['net']}]: {d['recorded']} variants, "
              f"{d['busy_virtual_s']:.2f}s busy, "
              f"{d['blocking_round_trips']} blocking RTs, "
              f"spec hit {hr:.0%}")
    print(f"makespan {s['virtual_time_s']:.2f}s virtual vs "
          f"{s['sum_record_virtual_s']:.2f}s summed record time "
          f"({s['recorded']} recorded, "
          f"{s['skipped_published']} already published, "
          f"{s['publishes']} published)")
    print("campaign:", json.dumps(s, indent=2))
    return campaign


if __name__ == "__main__":
    main()
