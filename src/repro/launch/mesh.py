"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  Single pod =
16x16 = 256 chips (v5e pod); multi-pod = 2 pods = 512 chips with a leading
'pod' axis (data-parallel across the DCI).

Version differences (AxisType / set_mesh) are absorbed by ``repro.compat``.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh, set_mesh  # re-export for launchers

__all__ = ["make_mesh", "set_mesh", "make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))
