"""Trace a record -> publish -> fetch -> replay lifecycle and dump the
virtual-time timeline.

    python -m repro.launch.trace --arch qwen2.5-3b --net wifi \
        --out /tmp/trace.json

Runs one workload through the full lifecycle with ``Workspace(trace=True)``
and writes a Chrome trace-event / Perfetto-loadable JSON file (open it at
https://ui.perfetto.dev or chrome://tracing), then prints the top spans by
virtual time and the attribution check — how much of the record session's
billed virtual time is covered by named spans.

This module is CLI-only: the tracing layer itself is ``repro.obs``.
"""
from __future__ import annotations

import argparse

from repro.api import Workspace
from repro.core import PROFILES


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="trace one record/publish/fetch/replay lifecycle on "
                    "the deterministic virtual clock")
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--net", default="wifi", choices=sorted(PROFILES))
    ap.add_argument("--passes", default="all",
                    help="record-session pass stack "
                         "(deferral,speculation,metasync | all | none)")
    ap.add_argument("--jobs", type=int, default=16,
                    help="interaction-plan jobs in the record session")
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--key", default="cody-demo-key")
    ap.add_argument("--out", default="/tmp/trace.json",
                    help="Chrome trace-event JSON output path")
    ap.add_argument("--top", type=int, default=15,
                    help="rows in the printed span summary")
    ap.add_argument("--strip-wall", action="store_true",
                    help="drop wall timestamps from the export (the "
                         "deterministic, byte-reproducible form)")
    args = ap.parse_args(argv)

    ws = Workspace(registry=":memory:", key=args.key.encode(),
                   net=args.net, record_passes=args.passes, trace=True)
    wl = ws.workload(args.arch, cache_len=args.cache_len,
                     block_k=args.block_k, batch=2, seq=args.seq)

    print(f"== record ({args.net}, passes={args.passes}, "
          f"jobs={args.jobs}) ==")
    rec = wl.record("prefill", jobs=args.jobs)
    srep = wl.sessions[-1][1]
    print(f"   virtual {srep['virtual_time_s']:.3f}s, "
          f"{srep['blocking_round_trips']} blocking RTs")

    print("== publish + fetch ==")
    wl.publish(rec)
    wl.fetch("prefill")

    print("== replay ==")
    rrep = wl.replay(artifact=rec, jobs=args.jobs)
    print(f"   virtual {rrep['virtual_time_s']:.3f}s, "
          f"{rrep['dispatches']} dispatches")

    tr = ws.tracer
    path = tr.dump(args.out, strip_wall=args.strip_wall)
    print(f"\ntrace: {path}  ({len(tr.events)} events; open in Perfetto)")

    att = tr.attributed_s("record")
    vt = srep["virtual_time_s"]
    frac = att / vt if vt else 1.0
    print(f"record attribution: {att:.3f}s of {vt:.3f}s virtual "
          f"({frac:.1%}) covered by named spans")

    print(f"\ntop {args.top} spans by virtual time:")
    print(tr.format_summary(top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
