"""Serving driver: continuous batching with fused-block decode, speculative
continuation, and (optionally) execution purely from signed recordings —
the paper's in-TEE replay mode.

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 8
    python -m repro.launch.serve --from-recordings /tmp/recordings --key k
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_shrink
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, cache_batch_axes_for
from repro.sharding import rules_for
from repro.training import steps as ST


def build_engine(cfg, *, n_slots: int, cache_len: int, block_k: int,
                 eos_id: int, params=None, recordings_dir: str = "",
                 key: bytes = b"", netem=None, speculate=True,
                 pipeline_depth: int = 4) -> Engine:
    mesh = make_host_mesh(model=1)
    rules = rules_for("serve", mesh.axis_names)
    batched_prefill = None
    fixed_prompt_len = None
    if cfg.family in ("ssm", "hybrid"):
        # recurrent state is not position-indexed: dropped pipeline tails
        # cannot be re-executed against an already-advanced state, so the
        # engine's metastate-only rollback is unsound here
        speculate = False
    if recordings_dir:
        from repro.core.replay import Replayer
        from repro.launch.record import recording_name
        rp = Replayer(key=key)
        pre = rp.load(f"{recordings_dir}/{recording_name(cfg.name, 'prefill')}"
                      .replace(cfg.name, cfg.name.replace("-smoke", "")))
        dec = rp.load(f"{recordings_dir}/{recording_name(cfg.name, 'decode')}"
                      .replace(cfg.name, cfg.name.replace("-smoke", "")))
        rp.warm(dec)   # decode joins the async pipeline with no cold start
        prefill_fn = lambda p, b: rp.execute(pre, p, b)
        decode_fn = lambda p, t, po, c: rp.execute(dec, p, t, po, c)
        # recorded executables are fixed-shape: prompts must match the
        # recorded prefill seq (callers read this off the engine)
        fixed_prompt_len = rp.manifest(pre)["static"].get("seq")
    else:
        prefill_fn = jax.jit(ST.make_prefill_step(cfg, rules, cache_len))
        decode_fn = jax.jit(
            ST.make_fused_decode_step(cfg, rules, k=block_k, eos_id=eos_id),
            donate_argnums=(3,))
        # grouped right-padded admission: attention families only (decode
        # masks rows >= pos; recurrent state is not position-indexed), and
        # the SWA ring layout depends on the true length
        if cfg.family in ("dense", "moe") and not cfg.sliding_window:
            batched_prefill = jax.jit(
                ST.make_batched_prefill_step(cfg, rules, cache_len))
    init_caches = lambda: M.init_cache(cfg, n_slots, cache_len)
    eng = Engine(params, prefill_fn, decode_fn, n_slots=n_slots,
                 cache_len=cache_len, block_k=block_k, eos_id=eos_id,
                 init_caches_fn=init_caches,
                 cache_batch_axes=cache_batch_axes_for(cfg), netem=netem,
                 speculate=speculate, pipeline_depth=pipeline_depth,
                 batched_prefill_fn=batched_prefill)
    eng.fixed_prompt_len = fixed_prompt_len
    return eng


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--no-speculate", action="store_true")
    ap.add_argument("--pipeline-depth", type=int, default=4)
    ap.add_argument("--from-recordings", default="")
    ap.add_argument("--key", default="cody-demo-key")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_shrink(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = build_engine(cfg, n_slots=args.slots, cache_len=args.cache_len,
                       block_k=args.block_k, eos_id=2, params=params,
                       recordings_dir=args.from_recordings,
                       key=args.key.encode(),
                       speculate=not args.no_speculate,
                       pipeline_depth=args.pipeline_depth)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = eng.fixed_prompt_len or int(rng.integers(4, 16))
        eng.submit(list(rng.integers(3, cfg.vocab_size, plen)), args.max_new)
    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    toks = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.0f} tok/s)")
    print("engine stats:", dict(eng.stats))
    print("speculator:", dict(eng.spec.stats))
    return outs, eng


if __name__ == "__main__":
    main()
