"""Serving driver CLI — a thin shim over ``repro.api``.

Continuous batching with fused-block decode, speculative continuation,
and (optionally) execution purely from signed recordings — the paper's
in-TEE replay mode.  Recordings come from a flat directory
(``--from-recordings``) or from the content-addressed registry
(``--from-registry``), the latter with chunked/resumable fetch over an
emulated network and collaborative record-on-miss:

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 8
    python -m repro.launch.serve --streams qwen2.5-3b,xlstm-350m --requests 8
    python -m repro.launch.serve --from-recordings /tmp/recordings --key k
    python -m repro.launch.serve --from-registry /tmp/recordings/registry \
        --net wifi --record-on-miss --key k

This module is CLI-only: channel selection, registry boot, record-on-miss
and multi-tenant wiring all live in ``repro.api``; ``build_channel`` /
``build_engine`` / ``build_scheduler`` / ``stream_kwargs`` are kept as
thin compatibility wrappers over ``Workspace``/``Workload``.  One
deliberate tightening: passing BOTH ``registry_dir`` and
``recordings_dir`` (previously registry silently won) and a registry
without a signing key (previously failed later, at client creation) now
raise ``ValueError`` up front.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import Workspace, stream_kwargs
from repro.configs import get_config, smoke_shrink
from repro.core import PROFILES, NetworkEmulator
from repro.models import model as M
from repro.serving.engine import Engine

__all__ = ["build_channel", "build_engine", "build_scheduler",
           "stream_kwargs", "main"]

# registry prefill recordings are fetched at this prompt length; the
# engine adapts admission via channel.fixed_prompt_len
REC_SEQ = 16


def _workspace_workload(cfg, *, cache_len, block_k, eos_id, n_slots,
                        registry_dir, key, netem):
    ws = Workspace(registry=registry_dir or None, key=key, net=netem)
    wl = ws.workload(cfg, cache_len=cache_len, block_k=block_k,
                     batch=n_slots, prefill_batch=1, seq=REC_SEQ,
                     eos_id=eos_id)
    return ws, wl


def build_channel(cfg, *, cache_len: int, block_k: int, eos_id: int = 2,
                  n_slots: int = 4, recordings_dir: str = "",
                  registry_dir: str = "", record_on_miss: bool = False,
                  key: bytes = b"", netem=None, bill_dispatches: bool = False):
    """Build the ExecutionChannel for one workload (live-jit / flat
    signed-replay / verified registry replay).  Returns
    ``(channel, registry_client_or_None)``."""
    ws, wl = _workspace_workload(cfg, cache_len=cache_len, block_k=block_k,
                                 eos_id=eos_id, n_slots=n_slots,
                                 registry_dir=registry_dir, key=key,
                                 netem=netem)
    channel = wl.channel(recordings_dir=recordings_dir,
                         record_on_miss=record_on_miss,
                         bill_dispatches=bill_dispatches)
    return channel, ws.registry_client


def build_engine(cfg, *, n_slots: int, cache_len: int, block_k: int,
                 eos_id: int, params=None, recordings_dir: str = "",
                 registry_dir: str = "", record_on_miss: bool = False,
                 key: bytes = b"", netem=None, speculate=True,
                 pipeline_depth: int = 4) -> Engine:
    """Single-workload path: one stream behind the classic Engine facade."""
    _ws, wl = _workspace_workload(cfg, cache_len=cache_len, block_k=block_k,
                                  eos_id=eos_id, n_slots=n_slots,
                                  registry_dir=registry_dir, key=key,
                                  netem=netem)
    return wl.engine(params=params, recordings_dir=recordings_dir,
                     record_on_miss=record_on_miss, speculate=speculate,
                     pipeline_depth=pipeline_depth)


def build_scheduler(archs, *, n_slots: int, cache_len: int, block_k: int,
                    eos_id: int = 2, netem=None, speculate: bool = True,
                    pipeline_depth: int = 4, smoke: bool = True,
                    max_live_slots=None, stall_limit=None, seed: int = 0):
    """Multi-workload path: one Scheduler, one stream per arch, each with
    its own live-jit channel, params, slots, and caches.  Returns
    ``(scheduler, {name: cfg})``."""
    ws = Workspace(net=netem)
    sched, wls = ws.scheduler(archs, n_slots=n_slots, cache_len=cache_len,
                              block_k=block_k, eos_id=eos_id, smoke=smoke,
                              speculate=speculate,
                              pipeline_depth=pipeline_depth,
                              max_live_slots=max_live_slots,
                              stall_limit=stall_limit, seed=seed)
    return sched, {name: wl.cfg for name, wl in wls.items()}


def _serve_multi(args, netem):
    archs = [a.strip() for a in args.streams.split(",") if a.strip()]
    sched, cfgs = build_scheduler(
        archs, n_slots=args.slots, cache_len=args.cache_len,
        block_k=args.block_k, netem=netem,
        speculate=not args.no_speculate,
        pipeline_depth=args.pipeline_depth, smoke=args.smoke)
    rng = np.random.default_rng(0)
    for name, cfg in cfgs.items():
        for _ in range(args.requests):
            plen = int(rng.integers(4, 16))
            sched.submit(name, list(rng.integers(3, cfg.vocab_size, plen)),
                         args.max_new)
    t0 = time.time()
    outs = sched.run()
    dt = time.time() - t0
    toks = sum(len(v) for per in outs.values() for v in per.values())
    print(f"served {len(cfgs)} streams x {args.requests} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.0f} tok/s)")
    for name, ex in sched.streams.items():
        print(f"  [{name}] stats: {dict(ex.stats)}")
    print("frontier:", dict(sched.frontier.stats))
    print("speculator:", dict(sched.spec.stats))
    return outs, sched


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--streams", default="",
                    help="comma-separated archs to serve CONCURRENTLY "
                         "through one Scheduler (multi-tenant mode)")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--no-speculate", action="store_true")
    ap.add_argument("--pipeline-depth", type=int, default=4)
    ap.add_argument("--from-recordings", default="")
    ap.add_argument("--from-registry", default="",
                    help="registry root to fetch recordings from")
    ap.add_argument("--record-on-miss", action="store_true",
                    help="on registry miss, record through the service's "
                         "single-flight lease")
    ap.add_argument("--net", default="none",
                    choices=["none"] + sorted(PROFILES),
                    help="emulated network profile for registry fetches")
    ap.add_argument("--key", default="cody-demo-key")
    args = ap.parse_args(argv)

    netem = None
    if args.net != "none":
        netem = NetworkEmulator(PROFILES[args.net])

    if args.streams:
        return _serve_multi(args, netem)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_shrink(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = build_engine(cfg, n_slots=args.slots, cache_len=args.cache_len,
                       block_k=args.block_k, eos_id=2, params=params,
                       recordings_dir=args.from_recordings,
                       registry_dir=args.from_registry,
                       record_on_miss=args.record_on_miss,
                       key=args.key.encode(), netem=netem,
                       speculate=not args.no_speculate,
                       pipeline_depth=args.pipeline_depth)
    # registry boot traffic, snapshotted BEFORE the engine starts billing
    # its own commit round trips into the same emulated link
    registry_net = dict(netem.snapshot()) if netem is not None else None
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = eng.fixed_prompt_len or int(rng.integers(4, 16))
        eng.submit(list(rng.integers(3, cfg.vocab_size, plen)), args.max_new)
    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    toks = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.0f} tok/s)")
    print("engine stats:", dict(eng.stats))
    print("speculator:", dict(eng.spec.stats))
    if eng.registry_client is not None:
        print("registry client:", dict(eng.registry_client.stats))
        if registry_net is not None:
            print("registry net (boot):", registry_net)
            print("total net (boot + serve):", netem.snapshot())
    return outs, eng


if __name__ == "__main__":
    main()
