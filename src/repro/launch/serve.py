"""Serving driver: continuous batching with fused-block decode, speculative
continuation, and (optionally) execution purely from signed recordings —
the paper's in-TEE replay mode.  Recordings come from a flat directory
(``--from-recordings``) or from the content-addressed registry
(``--from-registry``), the latter with chunked/resumable fetch over an
emulated network and collaborative record-on-miss.

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 8
    python -m repro.launch.serve --from-recordings /tmp/recordings --key k
    python -m repro.launch.serve --from-registry /tmp/recordings/registry \
        --net wifi --record-on-miss --key k
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_shrink
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, cache_batch_axes_for
from repro.sharding import rules_for
from repro.training import steps as ST


def _registry_replayer(cfg, mesh, rules, *, registry_dir: str, key: bytes,
                       n_slots: int, cache_len: int, block_k: int,
                       netem=None, record_on_miss: bool = False,
                       rec_seq: int = 16):
    """Boot a Replayer from the registry: fetch-by-key (chunked, resumable,
    netem-billed), verify, preload + warm — a replica boots from a registry
    hit without recompiling.  On miss, ``record_on_miss`` records through
    the service's single-flight lease with THIS engine's exact shapes."""
    from repro.core.attest import fingerprint
    from repro.core.recorder import (mesh_descriptor, record,
                                     topology_fingerprint)
    from repro.core.replay import Replayer
    from repro.launch.record import build_step, static_meta_for
    from repro.registry import (RegistryClient, RegistryService,
                                RecordingStore, key_arch, key_for)

    store = RecordingStore(registry_dir, key=key)
    service = RegistryService(store, signing_key=key)
    client = RegistryClient(service, netem=netem, key=key)
    mesh_fp = fingerprint(mesh_descriptor(mesh))
    config_fp = cfg.fingerprint()
    topo = topology_fingerprint()

    def _usable(fk: str, static: dict) -> bool:
        """An alternate published shape of this workload is substitutable
        iff the engine-visible shapes agree (prefill seq may differ: the
        engine adapts via fixed_prompt_len; decode ignores seq) AND it was
        recorded for this exact model config and hardware topology — a
        foreign-host or differently-sized recording would only fail later
        with TopologyMismatch/ReplayArgumentError."""
        meta = store.entry(fk)["meta"]
        static_meta = meta.get("static", {})
        return (all(static_meta.get(f) == static[f]
                    for f in ("batch", "cache_len", "block_k"))
                and meta.get("config_fingerprint", "") == config_fp
                and meta.get("topology", "") == topo)

    items = []
    for kind in ("prefill", "decode"):
        static = static_meta_for(
            kind, cache_len=cache_len, block_k=block_k,
            batch=1 if kind == "prefill" else n_slots, seq=rec_seq)
        reg_key = key_for(cfg.name, kind, {**static, "config_fp": config_fp},
                          mesh_fp)
        record_fn = None
        if not service.has(reg_key):
            found = [fk for fk in store.find(f"{key_arch(cfg.name)}/{kind}/")
                     if _usable(fk, static)]
            if found:
                # most recently published alternate wins — find() sorts by
                # key hash, which would make the choice arbitrary
                reg_key = max(found, key=lambda fk: store.entry(fk)["meta"]
                              .get("published_s", 0.0))
            elif record_on_miss:
                def record_fn(kind=kind, static=static, reg_key=reg_key):
                    fn, specs, donate = build_step(
                        cfg, kind, rules, cache_len=cache_len,
                        block_k=block_k, batch=static["batch"],
                        seq=static.get("seq", rec_seq))
                    return record(reg_key, fn, specs, mesh=mesh,
                                  donate_argnums=donate,
                                  config_fingerprint=cfg.fingerprint(),
                                  static_meta=static)
        items.append((reg_key, record_fn))
    rp = Replayer(key=key)
    pre, dec = client.into_replayer(rp, items, warm=True)
    return rp, pre, dec, client


def build_engine(cfg, *, n_slots: int, cache_len: int, block_k: int,
                 eos_id: int, params=None, recordings_dir: str = "",
                 registry_dir: str = "", record_on_miss: bool = False,
                 key: bytes = b"", netem=None, speculate=True,
                 pipeline_depth: int = 4) -> Engine:
    mesh = make_host_mesh(model=1)
    rules = rules_for("serve", mesh.axis_names)
    batched_prefill = None
    fixed_prompt_len = None
    registry_client = None
    if cfg.family in ("ssm", "hybrid"):
        # recurrent state is not position-indexed: dropped pipeline tails
        # cannot be re-executed against an already-advanced state, so the
        # engine's metastate-only rollback is unsound here
        speculate = False
    if registry_dir:
        rp, pre, dec, registry_client = _registry_replayer(
            cfg, mesh, rules, registry_dir=registry_dir, key=key,
            n_slots=n_slots, cache_len=cache_len, block_k=block_k,
            netem=netem, record_on_miss=record_on_miss)
        prefill_fn = lambda p, b: rp.execute(pre, p, b)
        decode_fn = lambda p, t, po, c: rp.execute(dec, p, t, po, c)
        fixed_prompt_len = rp.manifest(pre)["static"].get("seq")
    elif recordings_dir:
        from repro.core.replay import Replayer
        from repro.launch.record import recording_name
        rp = Replayer(key=key)
        pre = rp.load(f"{recordings_dir}/{recording_name(cfg.name, 'prefill')}")
        dec = rp.load(f"{recordings_dir}/{recording_name(cfg.name, 'decode')}")
        rp.warm(dec)   # decode joins the async pipeline with no cold start
        prefill_fn = lambda p, b: rp.execute(pre, p, b)
        decode_fn = lambda p, t, po, c: rp.execute(dec, p, t, po, c)
        # recorded executables are fixed-shape: prompts must match the
        # recorded prefill seq (callers read this off the engine)
        fixed_prompt_len = rp.manifest(pre)["static"].get("seq")
    else:
        prefill_fn = jax.jit(ST.make_prefill_step(cfg, rules, cache_len))
        decode_fn = jax.jit(
            ST.make_fused_decode_step(cfg, rules, k=block_k, eos_id=eos_id),
            donate_argnums=(3,))
        # grouped right-padded admission: attention families only (decode
        # masks rows >= pos; recurrent state is not position-indexed), and
        # the SWA ring layout depends on the true length
        if cfg.family in ("dense", "moe") and not cfg.sliding_window:
            batched_prefill = jax.jit(
                ST.make_batched_prefill_step(cfg, rules, cache_len))
    init_caches = lambda: M.init_cache(cfg, n_slots, cache_len)
    eng = Engine(params, prefill_fn, decode_fn, n_slots=n_slots,
                 cache_len=cache_len, block_k=block_k, eos_id=eos_id,
                 init_caches_fn=init_caches,
                 cache_batch_axes=cache_batch_axes_for(cfg), netem=netem,
                 speculate=speculate, pipeline_depth=pipeline_depth,
                 batched_prefill_fn=batched_prefill)
    eng.fixed_prompt_len = fixed_prompt_len
    eng.registry_client = registry_client
    return eng


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--no-speculate", action="store_true")
    ap.add_argument("--pipeline-depth", type=int, default=4)
    ap.add_argument("--from-recordings", default="")
    ap.add_argument("--from-registry", default="",
                    help="registry root to fetch recordings from")
    ap.add_argument("--record-on-miss", action="store_true",
                    help="on registry miss, record through the service's "
                         "single-flight lease")
    ap.add_argument("--net", default="none",
                    choices=["none", "wifi", "cellular", "local"],
                    help="emulated network profile for registry fetches")
    ap.add_argument("--key", default="cody-demo-key")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_shrink(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    netem = None
    if args.net != "none":
        from repro.core.netem import CELLULAR, LOCAL, WIFI, NetworkEmulator
        netem = NetworkEmulator(
            {"wifi": WIFI, "cellular": CELLULAR, "local": LOCAL}[args.net])
    eng = build_engine(cfg, n_slots=args.slots, cache_len=args.cache_len,
                       block_k=args.block_k, eos_id=2, params=params,
                       recordings_dir=args.from_recordings,
                       registry_dir=args.from_registry,
                       record_on_miss=args.record_on_miss,
                       key=args.key.encode(), netem=netem,
                       speculate=not args.no_speculate,
                       pipeline_depth=args.pipeline_depth)
    # registry boot traffic, snapshotted BEFORE the engine starts billing
    # its own commit round trips into the same emulated link
    registry_net = dict(netem.snapshot()) if netem is not None else None
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = eng.fixed_prompt_len or int(rng.integers(4, 16))
        eng.submit(list(rng.integers(3, cfg.vocab_size, plen)), args.max_new)
    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    toks = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.0f} tok/s)")
    print("engine stats:", dict(eng.stats))
    print("speculator:", dict(eng.spec.stats))
    if eng.registry_client is not None:
        print("registry client:", dict(eng.registry_client.stats))
        if registry_net is not None:
            print("registry net (boot):", registry_net)
            print("total net (boot + serve):", netem.snapshot())
    return outs, eng


if __name__ == "__main__":
    main()
