"""Serving driver: continuous batching with fused-block decode, speculative
continuation, and (optionally) execution purely from signed recordings —
the paper's in-TEE replay mode.  Recordings come from a flat directory
(``--from-recordings``) or from the content-addressed registry
(``--from-registry``), the latter with chunked/resumable fetch over an
emulated network and collaborative record-on-miss.

Execution is transport-agnostic: ``build_channel`` returns the
``ExecutionChannel`` (live-jit / signed-replay / netem-billed) a stream
decodes through, ``build_engine`` wires one stream through the layered
stack behind the classic ``Engine`` facade, and ``build_scheduler``
serves SEVERAL model families concurrently through one ``Scheduler``
(e.g. an attention family with speculation next to a recurrent family
with speculation gated off):

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 8
    python -m repro.launch.serve --streams qwen2.5-3b,xlstm-350m --requests 8
    python -m repro.launch.serve --from-recordings /tmp/recordings --key k
    python -m repro.launch.serve --from-registry /tmp/recordings/registry \
        --net wifi --record-on-miss --key k
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_shrink
from repro.core.channel import LiveChannel, NetemBilledChannel
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, cache_batch_axes_for
from repro.serving.scheduler import Scheduler
from repro.sharding import rules_for
from repro.training import steps as ST


def _registry_channel(cfg, mesh, rules, *, registry_dir: str, key: bytes,
                      n_slots: int, cache_len: int, block_k: int,
                      netem=None, record_on_miss: bool = False,
                      rec_seq: int = 16):
    """Boot a ReplayChannel from the registry: fetch-by-key (chunked,
    resumable, netem-billed), verify, preload + warm — a replica boots from
    a registry hit without recompiling.  On miss, ``record_on_miss``
    records through the service's single-flight lease with THIS engine's
    exact shapes.  The serving stack receives only the channel."""
    from repro.core.attest import fingerprint
    from repro.core.recorder import (mesh_descriptor, record,
                                     topology_fingerprint)
    from repro.core.replay import Replayer
    from repro.launch.record import build_step, static_meta_for
    from repro.registry import (RegistryClient, RegistryService,
                                RecordingStore, key_arch, key_for)

    store = RecordingStore(registry_dir, key=key)
    # record-on-miss runs the CODY two-party session over the same link
    # profile the client fetches through — cold boots bill realistic
    # distributed record cost, not just compile wall time
    service = RegistryService(
        store, signing_key=key,
        record_profile=netem.profile if netem is not None else None)
    client = RegistryClient(service, netem=netem, key=key)
    mesh_fp = fingerprint(mesh_descriptor(mesh))
    config_fp = cfg.fingerprint()
    topo = topology_fingerprint()

    def _usable(fk: str, static: dict) -> bool:
        """An alternate published shape of this workload is substitutable
        iff the engine-visible shapes agree (prefill seq may differ: the
        engine adapts via fixed_prompt_len; decode ignores seq) AND it was
        recorded for this exact model config and hardware topology — a
        foreign-host or differently-sized recording would only fail later
        with TopologyMismatch/ReplayArgumentError."""
        meta = store.entry(fk)["meta"]
        static_meta = meta.get("static", {})
        return (all(static_meta.get(f) == static[f]
                    for f in ("batch", "cache_len", "block_k"))
                and meta.get("config_fingerprint", "") == config_fp
                and meta.get("topology", "") == topo)

    items = []
    for kind in ("prefill", "decode"):
        static = static_meta_for(
            kind, cache_len=cache_len, block_k=block_k,
            batch=1 if kind == "prefill" else n_slots, seq=rec_seq)
        reg_key = key_for(cfg.name, kind, {**static, "config_fp": config_fp},
                          mesh_fp)
        record_fn = None
        if not service.has(reg_key):
            found = [fk for fk in store.find(f"{key_arch(cfg.name)}/{kind}/")
                     if _usable(fk, static)]
            if found:
                # most recently published alternate wins — find() sorts by
                # key hash, which would make the choice arbitrary
                reg_key = max(found, key=lambda fk: store.entry(fk)["meta"]
                              .get("published_s", 0.0))
            elif record_on_miss:
                def record_fn(session=None, kind=kind, static=static,
                              reg_key=reg_key):
                    # ``session`` is supplied by the service's lease: the
                    # miss records through a distributed RecordingSession
                    # over the service's configured profile
                    fn, specs, donate = build_step(
                        cfg, kind, rules, cache_len=cache_len,
                        block_k=block_k, batch=static["batch"],
                        seq=static.get("seq", rec_seq))
                    return record(reg_key, fn, specs, mesh=mesh,
                                  donate_argnums=donate,
                                  config_fingerprint=cfg.fingerprint(),
                                  static_meta=static, session=session)
        items.append((reg_key, record_fn))
    rp = Replayer(key=key)
    channel = client.into_channel(rp, items[0], items[1], warm=True)
    return channel, client


def build_channel(cfg, *, cache_len: int, block_k: int, eos_id: int = 2,
                  n_slots: int = 4, recordings_dir: str = "",
                  registry_dir: str = "", record_on_miss: bool = False,
                  key: bytes = b"", netem=None, bill_dispatches: bool = False):
    """Build the ExecutionChannel for one workload.

    Live-jit by default; signed-replay when ``recordings_dir`` /
    ``registry_dir`` is given (the paper's in-TEE mode — the channel never
    imports model code at decode time); wrap with ``bill_dispatches`` for
    the netem-billed record/emulation transport.  Returns
    ``(channel, registry_client_or_None)``."""
    mesh = make_host_mesh(model=1)
    rules = rules_for("serve", mesh.axis_names)
    registry_client = None
    if registry_dir:
        channel, registry_client = _registry_channel(
            cfg, mesh, rules, registry_dir=registry_dir, key=key,
            n_slots=n_slots, cache_len=cache_len, block_k=block_k,
            netem=netem, record_on_miss=record_on_miss)
    elif recordings_dir:
        from repro.core.channel import ReplayChannel
        from repro.core.replay import Replayer
        from repro.launch.record import recording_name
        rp = Replayer(key=key)
        pre = rp.load(f"{recordings_dir}/{recording_name(cfg.name, 'prefill')}")
        dec = rp.load(f"{recordings_dir}/{recording_name(cfg.name, 'decode')}")
        rp.warm(dec)   # decode joins the async pipeline with no cold start
        # recorded executables are fixed-shape: prompts must match the
        # recorded prefill seq (callers read this off the channel)
        channel = ReplayChannel(rp, pre, dec)
    else:
        prefill_fn = jax.jit(ST.make_prefill_step(cfg, rules, cache_len))
        decode_fn = jax.jit(
            ST.make_fused_decode_step(cfg, rules, k=block_k, eos_id=eos_id),
            donate_argnums=(3,))
        # grouped right-padded admission: attention families only (decode
        # masks rows >= pos; recurrent state is not position-indexed), and
        # the SWA ring layout depends on the true length
        batched_prefill = None
        if cfg.family in ("dense", "moe") and not cfg.sliding_window:
            batched_prefill = jax.jit(
                ST.make_batched_prefill_step(cfg, rules, cache_len))
        channel = LiveChannel(prefill_fn, decode_fn, batched_prefill)
    if bill_dispatches:
        channel = NetemBilledChannel(channel, netem)
    return channel, registry_client


def stream_kwargs(cfg, *, n_slots: int, cache_len: int, block_k: int,
                  eos_id: int, speculate: bool = True,
                  pipeline_depth: int = 4) -> dict:
    """Per-stream policy for ``Scheduler.add_stream`` derived from the
    model family: recurrent state is not position-indexed, so dropped
    pipeline tails cannot be re-executed against an already-advanced
    state — the engine's metastate-only rollback is unsound there and
    speculation is forced off."""
    if cfg.family in ("ssm", "hybrid"):
        speculate = False
    return dict(n_slots=n_slots, cache_len=cache_len, block_k=block_k,
                eos_id=eos_id,
                init_caches_fn=lambda: M.init_cache(cfg, n_slots, cache_len),
                cache_batch_axes=cache_batch_axes_for(cfg),
                speculate=speculate, pipeline_depth=pipeline_depth)


def build_engine(cfg, *, n_slots: int, cache_len: int, block_k: int,
                 eos_id: int, params=None, recordings_dir: str = "",
                 registry_dir: str = "", record_on_miss: bool = False,
                 key: bytes = b"", netem=None, speculate=True,
                 pipeline_depth: int = 4) -> Engine:
    """Single-workload path: one stream behind the classic Engine facade."""
    channel, registry_client = build_channel(
        cfg, cache_len=cache_len, block_k=block_k, eos_id=eos_id,
        n_slots=n_slots, recordings_dir=recordings_dir,
        registry_dir=registry_dir, record_on_miss=record_on_miss, key=key,
        netem=netem)
    kw = stream_kwargs(cfg, n_slots=n_slots, cache_len=cache_len,
                       block_k=block_k, eos_id=eos_id, speculate=speculate,
                       pipeline_depth=pipeline_depth)
    eng = Engine(params, channel=channel, netem=netem, **kw)
    eng.registry_client = registry_client
    return eng


def build_scheduler(archs, *, n_slots: int, cache_len: int, block_k: int,
                    eos_id: int = 2, netem=None, speculate: bool = True,
                    pipeline_depth: int = 4, smoke: bool = True,
                    max_live_slots=None, stall_limit=None, seed: int = 0):
    """Multi-workload path: one Scheduler, one stream per arch, each with
    its own live-jit channel, params, slots, and caches.  Returns
    ``(scheduler, {name: cfg})``."""
    sched = Scheduler(netem=netem, max_live_slots=max_live_slots,
                      stall_limit=stall_limit)
    cfgs = {}
    for i, arch in enumerate(archs):
        cfg = get_config(arch)
        if smoke:
            cfg = smoke_shrink(cfg)
        params = M.init_params(cfg, jax.random.PRNGKey(seed + i))
        channel, _ = build_channel(cfg, cache_len=cache_len,
                                   block_k=block_k, eos_id=eos_id,
                                   n_slots=n_slots, netem=netem)
        kw = stream_kwargs(cfg, n_slots=n_slots, cache_len=cache_len,
                           block_k=block_k, eos_id=eos_id,
                           speculate=speculate,
                           pipeline_depth=pipeline_depth)
        sched.add_stream(cfg.name, channel, params, **kw)
        cfgs[cfg.name] = cfg
    return sched, cfgs


def _serve_multi(args, netem):
    archs = [a.strip() for a in args.streams.split(",") if a.strip()]
    sched, cfgs = build_scheduler(
        archs, n_slots=args.slots, cache_len=args.cache_len,
        block_k=args.block_k, netem=netem,
        speculate=not args.no_speculate,
        pipeline_depth=args.pipeline_depth, smoke=args.smoke)
    rng = np.random.default_rng(0)
    for name, cfg in cfgs.items():
        for _ in range(args.requests):
            plen = int(rng.integers(4, 16))
            sched.submit(name, list(rng.integers(3, cfg.vocab_size, plen)),
                         args.max_new)
    t0 = time.time()
    outs = sched.run()
    dt = time.time() - t0
    toks = sum(len(v) for per in outs.values() for v in per.values())
    print(f"served {len(cfgs)} streams x {args.requests} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.0f} tok/s)")
    for name, ex in sched.streams.items():
        print(f"  [{name}] stats: {dict(ex.stats)}")
    print("frontier:", dict(sched.frontier.stats))
    print("speculator:", dict(sched.spec.stats))
    return outs, sched


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--streams", default="",
                    help="comma-separated archs to serve CONCURRENTLY "
                         "through one Scheduler (multi-tenant mode)")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--no-speculate", action="store_true")
    ap.add_argument("--pipeline-depth", type=int, default=4)
    ap.add_argument("--from-recordings", default="")
    ap.add_argument("--from-registry", default="",
                    help="registry root to fetch recordings from")
    ap.add_argument("--record-on-miss", action="store_true",
                    help="on registry miss, record through the service's "
                         "single-flight lease")
    from repro.core.netem import PROFILES
    ap.add_argument("--net", default="none",
                    choices=["none"] + sorted(PROFILES),
                    help="emulated network profile for registry fetches")
    ap.add_argument("--key", default="cody-demo-key")
    args = ap.parse_args(argv)

    netem = None
    if args.net != "none":
        from repro.core.netem import NetworkEmulator
        netem = NetworkEmulator(PROFILES[args.net])

    if args.streams:
        return _serve_multi(args, netem)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_shrink(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = build_engine(cfg, n_slots=args.slots, cache_len=args.cache_len,
                       block_k=args.block_k, eos_id=2, params=params,
                       recordings_dir=args.from_recordings,
                       registry_dir=args.from_registry,
                       record_on_miss=args.record_on_miss,
                       key=args.key.encode(), netem=netem,
                       speculate=not args.no_speculate,
                       pipeline_depth=args.pipeline_depth)
    # registry boot traffic, snapshotted BEFORE the engine starts billing
    # its own commit round trips into the same emulated link
    registry_net = dict(netem.snapshot()) if netem is not None else None
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = eng.fixed_prompt_len or int(rng.integers(4, 16))
        eng.submit(list(rng.integers(3, cfg.vocab_size, plen)), args.max_new)
    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    toks = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.0f} tok/s)")
    print("engine stats:", dict(eng.stats))
    print("speculator:", dict(eng.spec.stats))
    if eng.registry_client is not None:
        print("registry client:", dict(eng.registry_client.stats))
        if registry_net is not None:
            print("registry net (boot):", registry_net)
            print("total net (boot + serve):", netem.snapshot())
    return outs, eng


if __name__ == "__main__":
    main()
