# ruff: noqa: E402
# (XLA_FLAGS must be set before any jax-importing module is touched)
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  The dry-run proves the distribution config is
# coherent: every (arch x shape) cell must lower AND compile for the 16x16
# single-pod mesh and the 2x16x16 multi-pod mesh.

import argparse
import dataclasses
import glob
import json
import shutil
import tempfile
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo as hlo_an
from repro.analysis import roofline as rf
from repro.configs import (ARCHS, SHAPES, cell_applicable, get_config,
                           input_specs)
from repro import compat
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.models import model as M
from repro.sharding import rules_for, shardings_for, spec
from repro.training import steps as ST


def batch_axes(cfg, batch):
    ax = {}
    for k in batch:
        if k in ("tokens", "labels"):
            ax[k] = ("batch", "seq")
        else:
            ax[k] = ("batch", None, None)
    return ax


def build_cell(cfg, shape_name, mesh, overrides):
    """-> (fn, args, in_shardings, out_shardings, donate)"""
    cell = SHAPES[shape_name]
    mode = overrides.get("rules_mode") or \
        ("train" if cell.kind == "train" else "serve")
    rules = rules_for(mode, mesh.axis_names, fsdp=overrides.get("fsdp", True))
    ns = lambda s: NamedSharding(mesh, s)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    sp = lambda axes, shape: ns(spec(axes, rules, shape, mesh_shape))

    if cell.kind == "train":
        fn = ST.make_train_step(cfg, rules, remat=overrides.get("remat", "full"))
        state = ST.abstract_train_state(cfg)
        batch = input_specs(cfg, shape_name)
        st_sh = shardings_for(ST.train_state_axes(cfg), state, mesh, rules)
        b_sh = shardings_for(batch_axes(cfg, batch), batch, mesh, rules)
        metrics_sh = {k: ns(P()) for k in
                      ("loss", "ce", "aux", "grad_norm", "lr")}
        return (fn, (state, batch), (st_sh, b_sh), (st_sh, metrics_sh), (0,))

    params = M.abstract_params(cfg)
    p_axes = M.param_axes(cfg)
    if overrides.get("quant"):
        from repro.serving.quant import abstract_quantized, quantized_axes
        p_axes = quantized_axes(p_axes, params)
        params = abstract_quantized(params)
    p_sh = shardings_for(p_axes, params, mesh, rules)
    B = cell.batch
    if cell.kind == "prefill":
        fn = ST.make_prefill_step(cfg, rules, cache_len=cell.seq)
        batch = input_specs(cfg, shape_name)
        b_sh = shardings_for(batch_axes(cfg, batch), batch, mesh, rules)
        enc_S = cfg.encdec.encoder_seq if cfg.family == "audio" else 0
        cache_abs = jax.eval_shape(
            lambda: M.init_cache(cfg, B, cell.seq, enc_S=enc_S))
        cache_sh = shardings_for(M.cache_axes(cfg), cache_abs, mesh, rules)
        out_sh = ({"next_tokens": sp(("batch",), (B,)),
                   "last_logits": sp(("batch", "vocab"), (B, cfg.vocab_size))},
                  cache_sh)
        return (fn, (params, batch), (p_sh, b_sh), out_sh, ())

    # decode
    fn = ST.make_decode_step(cfg, rules)
    specs_ = input_specs(cfg, shape_name)
    cache_sh = shardings_for(M.cache_axes(cfg), specs_["caches"], mesh, rules)
    dp = sp(("batch",), (B,))
    in_sh = (p_sh, dp, dp, cache_sh)
    out_sh = (dp, sp(("batch", "vocab"), (B, cfg.vocab_size)), cache_sh)
    return (fn, (params, specs_["tokens"], specs_["pos"], specs_["caches"]),
            in_sh, out_sh, (3,))


def run_cell(arch, shape_name, multi_pod, overrides=None, keep_text=False):
    overrides = overrides or {}
    cfg = get_config(arch)
    for k, v in overrides.get("cfg", {}).items():
        cfg = dataclasses.replace(cfg, **{k: v})
    cell = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "num_chips": 512 if multi_pod else 256}
    skip = cell_applicable(cfg, shape_name)
    if skip:
        rec.update(status="skip", reason=skip)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_sh, out_sh, donate = build_cell(
            cfg, shape_name, mesh, overrides)
        t0 = time.time()
        dump_dir = tempfile.mkdtemp(prefix="hlo_spmd_")
        with set_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile(compiler_options={
                "xla_dump_to": dump_dir,
                "xla_dump_hlo_pass_re": "spmd-partitioning"})
            t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        ca = compat.cost_analysis(compiled)
        text = compiled.as_text()
        # dtype-true (bf16) post-SPMD module for the roofline byte counts;
        # the final scheduled module inflates bf16 to f32 (CPU legalization)
        spmd_files = sorted(glob.glob(
            os.path.join(dump_dir, "*after_spmd-partitioning*.txt")),
            key=os.path.getsize)
        if spmd_files:
            spmd_text = open(spmd_files[-1]).read()
            cost = hlo_an.analyze(spmd_text, rec["num_chips"], mode="spmd")
        else:
            cost = hlo_an.analyze(text, rec["num_chips"])
        shutil.rmtree(dump_dir, ignore_errors=True)
        mf = rf.analytic_model_flops(cfg, cell.kind, cell.batch, cell.seq)
        roof = rf.from_hlo(cost, mf, rec["num_chips"])
        rec.update(
            status="ok", t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            bytes_per_device=int(mem.argument_size_in_bytes +
                                 mem.temp_size_in_bytes +
                                 mem.output_size_in_bytes -
                                 mem.alias_size_in_bytes),
            # dtype-true resident state (params/caches/opt+outputs); the CPU
            # backend's temp is inflated by hoisted bf16->f32 legalization
            # copies that do not exist on TPU (see EXPERIMENTS.md §Dry-run)
            resident_bytes=int(mem.argument_size_in_bytes +
                               mem.output_size_in_bytes -
                               mem.alias_size_in_bytes),
            arg_bytes=int(mem.argument_size_in_bytes),
            temp_bytes=int(mem.temp_size_in_bytes),
            out_bytes=int(mem.output_size_in_bytes),
            alias_bytes=int(mem.alias_size_in_bytes),
            xla_flops_per_dev=float(ca.get("flops", 0.0)),
            hlo=cost, roofline=roof.as_dict(),
            model_flops_total=mf, hlo_text_len=len(text))
        if keep_text:
            rec["hlo_text"] = text
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--rules", default="", help="override rules mode, e.g. train_zero")
    ap.add_argument("--serve-quant", action="store_true",
                    help="int8 weight quantization for serve cells")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache for decode cells")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    overrides = {"remat": args.remat, "fsdp": not args.no_fsdp,
                 "rules_mode": args.rules or None,
                 "quant": args.serve_quant,
                 "cfg": {"kv_quant": True} if args.kv_quant else {}}
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, overrides)
                tag = f"-{args.tag}" if args.tag else ""
                name = f"{arch}_{shape}_{rec['mesh']}{tag}.json"
                with open(os.path.join(args.out, name), "w") as f:
                    json.dump(rec, f, indent=1)
                s = rec["status"]
                n_ok += s == "ok"
                n_skip += s == "skip"
                n_err += s == "error"
                if s == "ok":
                    r = rec["roofline"]
                    print(f"[{s:5s}] {arch:22s} {shape:12s} {rec['mesh']:8s} "
                          f"mem/dev={rec['bytes_per_device']/2**30:6.2f}GiB "
                          f"Tc={r['t_compute_s']:.3e} Tm={r['t_memory_s']:.3e} "
                          f"Tcoll={r['t_collective_s']:.3e} dom={r['dominant']:10s} "
                          f"compile={rec['t_compile_s']:.0f}s", flush=True)
                else:
                    print(f"[{s:5s}] {arch:22s} {shape:12s} {rec['mesh']:8s} "
                          f"{rec.get('reason', rec.get('error', ''))[:100]}",
                          flush=True)
    print(f"done: ok={n_ok} skip={n_skip} error={n_err}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
