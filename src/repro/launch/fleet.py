"""Fleet serving CLI — a thin shim over ``Workspace.fleet``.

Boots a pool of replay replicas (live-jit when no registry is given,
warm registry boot with ``--from-registry``), generates deterministic
open-loop traffic, serves it, and prints per-tenant latency quantiles
plus the pool/balancer accounting:

    python -m repro.launch.fleet --tenants qwen2.5-3b,xlstm-350m \
        --replicas 3 --policy least_loaded --rate 12 --horizon 2
    python -m repro.launch.fleet --from-registry /tmp/reg --key k \
        --net wifi --record-on-miss --regions 2 --policy cache_affinity
"""
from __future__ import annotations

import argparse
import json
import time

from repro.api import Workspace
from repro.core import PROFILES
from repro.fleet import POLICIES, OpenLoopTraffic, TenantMix

# registry prefill recordings pin the prompt shape; live fleets may vary
REC_SEQ = 16


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", default="qwen2.5-3b",
                    help="comma-separated archs, one stream per tenant")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", default="round_robin", choices=POLICIES)
    ap.add_argument("--rate", type=float, default=10.0,
                    help="per-tenant Poisson arrival rate (requests/s)")
    ap.add_argument("--horizon", type=float, default=2.0,
                    help="virtual seconds of open-loop traffic")
    ap.add_argument("--burst-x", type=float, default=4.0)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--block-k", type=int, default=4)
    ap.add_argument("--tick", type=float, default=0.02)
    ap.add_argument("--regions", type=int, default=1)
    ap.add_argument("--queue-limit", type=int, default=None)
    ap.add_argument("--autoscale", action="store_true")
    ap.add_argument("--from-registry", default="",
                    help="registry root; replicas boot warm from it")
    ap.add_argument("--record-on-miss", action="store_true")
    ap.add_argument("--net", default="wifi",
                    choices=["none"] + sorted(PROFILES))
    ap.add_argument("--key", default="cody-demo-key")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    registry = args.from_registry or None
    ws = Workspace(registry=registry,
                   key=args.key.encode() if registry else b"",
                   net=None if args.net == "none" else args.net)
    archs = [a.strip() for a in args.tenants.split(",") if a.strip()]
    wls = [ws.workload(a, cache_len=args.cache_len, block_k=args.block_k,
                       batch=args.slots, seq=REC_SEQ) for a in archs]
    pool, _ = ws.fleet(wls, replicas=args.replicas, policy=args.policy,
                       tick_s=args.tick, regions=args.regions,
                       record_on_miss=args.record_on_miss,
                       queue_limit=args.queue_limit,
                       autoscale=args.autoscale, seed=args.seed)
    for r in pool.replicas:
        print(f"replica {r.name}: region r{r.region}, "
              f"boot {r.boot_virtual_s:.3f}s virtual")

    mixes = [TenantMix(wl.cfg.name, args.rate,
                       prompt_len=REC_SEQ if registry else (4, 12),
                       max_new=(4, args.max_new),
                       vocab=min(wl.cfg.vocab_size, 256)) for wl in wls]
    traffic = OpenLoopTraffic(mixes, seed=args.seed, burst_every_s=1.0,
                              burst_len_s=0.25, burst_x=args.burst_x)
    arrivals = traffic.generate(args.horizon)
    print(f"open-loop traffic: {len(arrivals)} arrivals over "
          f"{args.horizon}s virtual ({args.policy})")
    t0 = time.time()
    outputs = pool.run(arrivals)
    dt = time.time() - t0
    toks = sum(len(v) for v in outputs.values())
    print(f"served {len(outputs)}/{len(arrivals)} requests, {toks} tokens "
          f"in {dt:.2f}s wall / {pool.clock:.2f}s virtual")
    for wl in wls:
        q = ws.metrics.quantiles("fleet_request_latency_s",
                                 pool=pool.name, tenant=wl.cfg.name)
        print(f"  [{wl.cfg.name}] latency: {q}")
    print("pool:", json.dumps(pool.stats(), indent=2))
    return outputs, pool


if __name__ == "__main__":
    main()
