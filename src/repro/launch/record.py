"""The CODY "cloud dryrun service" CLI: produce signed recordings.

    python -m repro.launch.record --arch qwen2.5-3b --smoke \
        --kinds prefill,decode --out /tmp/recordings --key secret

Recordings are keyed by (arch, kind, shape, mesh fingerprint); the client
TEE replays them via repro.launch.replay / serving.Engine(use recordings).
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_shrink
from repro.core.recorder import record
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.sharding import rules_for
from repro.training import steps as ST


def recording_name(arch: str, kind: str, extra: str = "") -> str:
    return f"{arch}_{kind}{('_' + extra) if extra else ''}.codyrec"


def build_step(cfg, kind: str, rules, *, cache_len: int, block_k: int = 8,
               batch: int = 1, seq: int = 32):
    params = M.abstract_params(cfg)
    if kind == "prefill":
        fn = ST.make_prefill_step(cfg, rules, cache_len=cache_len)
        batch_spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        return fn, (params, batch_spec), ()
    if kind == "decode":
        fn = ST.make_fused_decode_step(cfg, rules, k=block_k)
        caches = jax.eval_shape(lambda: M.init_cache(cfg, batch, cache_len))
        toks = jax.ShapeDtypeStruct((batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
        return fn, (params, toks, pos, caches), (3,)
    raise ValueError(kind)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--kinds", default="prefill,decode")
    ap.add_argument("--out", default="/tmp/recordings")
    ap.add_argument("--key", default="cody-demo-key")
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_shrink(cfg)
    os.makedirs(args.out, exist_ok=True)
    mesh = make_host_mesh(model=1)
    rules = rules_for("serve", mesh.axis_names)
    for kind in args.kinds.split(","):
        fn, specs, donate = build_step(
            cfg, kind, rules, cache_len=args.cache_len,
            block_k=args.block_k, batch=args.batch, seq=args.seq)
        rec = record(f"{args.arch}:{kind}", fn, specs, mesh=mesh,
                     donate_argnums=donate,
                     config_fingerprint=cfg.fingerprint(),
                     static_meta={"kind": kind, "cache_len": args.cache_len,
                                  "block_k": args.block_k,
                                  "batch": args.batch, "seq": args.seq})
        path = os.path.join(args.out, recording_name(args.arch, kind))
        rec.save(path, args.key.encode())
        print(f"recorded {kind}: {path} "
              f"({len(rec.payload)/1e3:.1f} kB executable, "
              f"{rec.manifest['record_wall_s']:.1f}s record time)")


if __name__ == "__main__":
    main()
