"""The CODY "cloud dryrun service" CLI — a thin shim over ``repro.api``.

    python -m repro.launch.record --arch qwen2.5-3b --smoke \
        --kinds prefill,decode --out /tmp/recordings --key secret \
        --net wifi --passes all

Each record runs as a distributed ``RecordingSession`` (device proxy +
cloud dryrun over the ``--net`` emulated link) with the paper's record
optimizations selected by ``--passes``, and prints the session report:
virtual record time, blocking/async round trips, wire bytes, per-pass
accounting.  Recordings are identified by ``registry.key_for`` — the
same key the serve CLI fetches by — and written both as a flat
``.codyrec`` file (legacy/offline path) and into the content-addressed
registry at ``--registry`` (delta-published).

This module is CLI-only: all lifecycle logic lives in ``repro.api``
(``Workspace``/``Workload``); ``build_step`` / ``static_meta_for`` /
``recording_name`` / ``format_session_report`` are re-exported here for
backward compatibility.
"""
from __future__ import annotations

import argparse
import os

from repro.api import (Workspace, build_step, format_session_report,
                       recording_name, static_meta_for)
from repro.core import PROFILES

__all__ = ["build_step", "static_meta_for", "recording_name",
           "format_session_report", "main"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--kinds", default="prefill,decode")
    ap.add_argument("--out", default="/tmp/recordings")
    ap.add_argument("--registry", default=None,
                    help="registry root (default: <out>/registry)")
    ap.add_argument("--no-registry", action="store_true",
                    help="skip registry publishing (flat files only)")
    ap.add_argument("--key", default="cody-demo-key")
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode batch = number of serving slots (match "
                         "serve --slots)")
    ap.add_argument("--prefill-batch", type=int, default=1,
                    help="prefill batch (default 1: the engine admits "
                         "prompts per request, so serve fetches batch-1 "
                         "prefill recordings)")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--net", default="local", choices=sorted(PROFILES),
                    help="emulated device<->cloud link the recording "
                         "session runs over")
    ap.add_argument("--passes", default="all",
                    help="comma list of record-session optimization passes "
                         "(deferral,speculation,metasync) | all | none")
    ap.add_argument("--devices", type=int, default=1,
                    help="> 1 fans the kinds out across a device pool "
                         "(campaign API) instead of recording serially")
    args = ap.parse_args(argv)

    registry = None
    if not args.no_registry:
        registry = args.registry or os.path.join(args.out, "registry")
    ws = Workspace(registry=registry, key=args.key.encode(), net=args.net,
                   record_passes=args.passes)
    wl = ws.workload(args.arch, smoke=args.smoke, cache_len=args.cache_len,
                     block_k=args.block_k, batch=args.batch,
                     prefill_batch=args.prefill_batch, seq=args.seq)
    os.makedirs(args.out, exist_ok=True)
    kinds = [k for k in args.kinds.split(",") if k.strip()]
    if args.devices > 1:
        # fan the kinds out across a device pool; each finished variant
        # publishes through the campaign's multi-variant lease
        campaign = ws.campaign([(wl, k) for k in kinds],
                               devices=args.devices,
                               name=f"record-{args.arch}")
        recs = campaign.run()
        for kind in kinds:
            rec = recs.get(wl.key(kind))
            if rec is None:
                print(f"skipped {kind}: already published / leased")
                continue
            path = os.path.join(args.out, recording_name(args.arch, kind))
            rec.save(path, ws.key)
            print(f"recorded {kind}: {path} "
                  f"({len(rec.payload)/1e3:.1f} kB executable)")
            print("  " + format_session_report(
                rec.manifest["record_session"]))
        s = campaign.stats()
        print(f"campaign[{s['devices']} devices]: "
              f"{s['virtual_time_s']:.2f}s virtual makespan vs "
              f"{s['sum_record_virtual_s']:.2f}s summed, "
              f"{s['publishes']} published")
        return
    for kind in kinds:
        # one two-party session per recording: fresh device proxy, fresh
        # speculation history, per-recording report
        rec = wl.record(kind)
        path = os.path.join(args.out, recording_name(args.arch, kind))
        rec.save(path, ws.key)
        line = (f"recorded {kind}: {path} "
                f"({len(rec.payload)/1e3:.1f} kB executable, "
                f"{rec.manifest['record_wall_s']:.1f}s record time)")
        if registry is not None:
            pub = wl.publish(rec)
            line += (f"; published {pub['key']} v{pub['version']} "
                     f"({pub['wire_bytes']/1e3:.1f} kB wire, "
                     f"{pub['chunks_new']} new / "
                     f"{pub['chunks_reused']} reused chunks)")
        print(line)
        print("  " + format_session_report(rec.manifest["record_session"]))


if __name__ == "__main__":
    main()
