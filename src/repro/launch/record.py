"""The CODY "cloud dryrun service" CLI: produce signed recordings and
publish them into the recording registry.

    python -m repro.launch.record --arch qwen2.5-3b --smoke \
        --kinds prefill,decode --out /tmp/recordings --key secret \
        --net wifi --passes all

Each record runs as a distributed ``RecordingSession`` (device proxy +
cloud dryrun over the ``--net`` emulated link) with the paper's record
optimizations selected by ``--passes`` (any of deferral, speculation,
metasync; "all"/"none"), and prints the session report: virtual record
time, blocking/async round trips, wire bytes, per-pass accounting.

Recordings are identified by ``registry.key_for(arch, kind, shapes,
mesh_fp)`` — the same key the serve CLI fetches by and the replayer
caches executables under.  Each recording is written both as a flat
``.codyrec`` file (legacy/offline path) and into the content-addressed
registry at ``--registry`` (delta-published: a re-record after a config
tweak ships only changed chunks).
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_shrink
from repro.core.attest import fingerprint
from repro.core.netem import PROFILES
from repro.core.recorder import mesh_descriptor, record
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.record import RecordingSession, resolve_passes
from repro.registry import RecordingStore, RegistryService, key_arch, key_for
from repro.sharding import rules_for
from repro.training import steps as ST


def format_session_report(rep: dict) -> str:
    """One-line summary of a RecordingSession report for CLI output."""
    mb = (rep["bytes_sent"] + rep["bytes_received"]) / 1e6
    passes = "+".join(rep["passes"]) or "naive"
    return (f"session[{rep['net']}|{passes}]: "
            f"{rep['virtual_time_s']:.2f}s virtual, "
            f"{rep['blocking_round_trips']} blocking / "
            f"{rep['async_round_trips']} async RTs, {mb:.2f} MB, "
            f"{rep['jobs']} jobs")


def recording_name(arch: str, kind: str, extra: str = "") -> str:
    """Flat on-disk filename for a recording (identity normalization is
    shared with the registry via ``key_arch``)."""
    return f"{key_arch(arch)}_{kind}{('_' + extra) if extra else ''}.codyrec"


def build_step(cfg, kind: str, rules, *, cache_len: int, block_k: int = 8,
               batch: int = 1, seq: int = 32):
    params = M.abstract_params(cfg)
    if kind == "prefill":
        fn = ST.make_prefill_step(cfg, rules, cache_len=cache_len)
        batch_spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        return fn, (params, batch_spec), ()
    if kind == "decode":
        fn = ST.make_fused_decode_step(cfg, rules, k=block_k)
        caches = jax.eval_shape(lambda: M.init_cache(cfg, batch, cache_len))
        toks = jax.ShapeDtypeStruct((batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
        return fn, (params, toks, pos, caches), (3,)
    raise ValueError(kind)


def static_meta_for(kind: str, *, cache_len: int, block_k: int, batch: int,
                    seq: int) -> dict:
    """The shape/static description that parameterizes ``build_step`` —
    also the ``shapes`` component of the registry key, so record and
    serve derive identical keys from identical CLI arguments.  ``seq``
    only shapes prefill (decode steps one token per slot per iteration),
    so it is excluded from decode identity: a decode recording serves any
    prompt length."""
    static = {"kind": kind, "cache_len": cache_len, "block_k": block_k,
              "batch": batch}
    if kind == "prefill":
        static["seq"] = seq
    return static


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--kinds", default="prefill,decode")
    ap.add_argument("--out", default="/tmp/recordings")
    ap.add_argument("--registry", default=None,
                    help="registry root (default: <out>/registry)")
    ap.add_argument("--no-registry", action="store_true",
                    help="skip registry publishing (flat files only)")
    ap.add_argument("--key", default="cody-demo-key")
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-k", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode batch = number of serving slots (match "
                         "serve --slots)")
    ap.add_argument("--prefill-batch", type=int, default=1,
                    help="prefill batch (default 1: the engine admits "
                         "prompts per request, so serve fetches batch-1 "
                         "prefill recordings)")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--net", default="local", choices=sorted(PROFILES),
                    help="emulated device<->cloud link the recording "
                         "session runs over")
    ap.add_argument("--passes", default="all",
                    help="comma list of record-session optimization passes "
                         "(deferral,speculation,metasync) | all | none")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_shrink(cfg)
    os.makedirs(args.out, exist_ok=True)
    signing_key = args.key.encode()
    service = None
    if not args.no_registry:
        registry_root = args.registry or os.path.join(args.out, "registry")
        store = RecordingStore(registry_root, key=signing_key)
        service = RegistryService(store, signing_key=signing_key)
    mesh = make_host_mesh(model=1)
    mesh_fp = fingerprint(mesh_descriptor(mesh))
    rules = rules_for("serve", mesh.axis_names)
    for kind in args.kinds.split(","):
        # --batch sizes the decode step (the serving slot count); prefill
        # defaults to batch=1, the engine's per-request admission shape
        batch = args.prefill_batch if kind == "prefill" else args.batch
        static = static_meta_for(kind, cache_len=args.cache_len,
                                 block_k=args.block_k, batch=batch,
                                 seq=args.seq)
        fn, specs, donate = build_step(
            cfg, kind, rules, cache_len=args.cache_len,
            block_k=args.block_k, batch=batch, seq=args.seq)
        # config fingerprint is part of recording identity: two sizes of
        # one arch (e.g. smoke-shrunk vs full) must never share a key
        key = key_for(args.arch, kind,
                      {**static, "config_fp": cfg.fingerprint()}, mesh_fp)
        # one two-party session per recording: fresh device proxy, fresh
        # speculation history, per-recording report
        session = RecordingSession.for_profile(
            PROFILES[args.net], passes=resolve_passes(args.passes))
        rec = record(key, fn, specs, mesh=mesh,
                     donate_argnums=donate,
                     config_fingerprint=cfg.fingerprint(),
                     static_meta=static, session=session)
        path = os.path.join(args.out, recording_name(args.arch, kind))
        rec.save(path, signing_key)
        line = (f"recorded {kind}: {path} "
                f"({len(rec.payload)/1e3:.1f} kB executable, "
                f"{rec.manifest['record_wall_s']:.1f}s record time)")
        if service is not None:
            pub = service.publish(key, rec)
            line += (f"; published {key} v{pub['version']} "
                     f"({pub['wire_bytes']/1e3:.1f} kB wire, "
                     f"{pub['chunks_new']} new / "
                     f"{pub['chunks_reused']} reused chunks)")
        print(line)
        print("  " + format_session_report(session.report()))


if __name__ == "__main__":
    main()
