"""Per-epoch signing-key rotation — an HKDF-style forward ratchet.

A ``KeySchedule`` owns a root secret and derives one signing key per
epoch by chaining HMAC states::

    state_0   = HMAC(root,    "repro-attest/state")
    state_e+1 = HMAC(state_e, "repro-attest/ratchet")
    key_e     = HMAC(state_e, "repro-attest/sign")

Signatures are BOUND to their epoch (``"{epoch}:{hexmac}"``): a verifier
holding the same schedule re-derives ``key_e`` for any already-existing
epoch — old recordings stay verifiable after rotation — while an epoch
beyond the schedule's current one raises ``FutureEpochError`` (a forged
epoch tag, or a verifier that must catch up before trusting anything).

The schedule is the ``Workspace``-owned credential the transparency log
and replay quotes sign under; the raw recording HMAC
(``core.attest.sign``) is unchanged — this layer is additive.  No
model/registry/network imports: the offline verifier ships this module.
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
from typing import List

from repro.core.attest import FutureEpochError, fingerprint

_STATE_LABEL = b"repro-attest/state"
_RATCHET_LABEL = b"repro-attest/ratchet"
_SIGN_LABEL = b"repro-attest/sign"


def _hkdf_step(key: bytes, label: bytes) -> bytes:
    return hmac.new(key, label, hashlib.sha256).digest()


@dataclasses.dataclass(frozen=True)
class EpochKey:
    """One epoch's signing material, pinned to the schedule that issued
    it.  Becomes STALE the moment the schedule rotates past its epoch —
    ``Workspace`` refuses stale epoch keys at construction."""
    epoch: int
    material: bytes
    schedule: "KeySchedule"

    @property
    def stale(self) -> bool:
        return self.epoch < self.schedule.epoch


class KeySchedule:
    """Root secret -> per-epoch signing keys, forward-ratcheted."""

    def __init__(self, root: bytes):
        if not root:
            raise ValueError("KeySchedule requires a non-empty root secret")
        self.root = bytes(root)
        self._states: List[bytes] = [_hkdf_step(self.root, _STATE_LABEL)]

    # ---------------------------------------------------------- rotation --
    @property
    def epoch(self) -> int:
        return len(self._states) - 1

    def rotate(self) -> int:
        """Advance to the next epoch; returns the new epoch number.
        Every already-derived epoch stays verifiable (states are kept —
        verification of history is the schedule's whole job)."""
        self._states.append(_hkdf_step(self._states[-1], _RATCHET_LABEL))
        return self.epoch

    def key_for_epoch(self, epoch: int) -> bytes:
        if not isinstance(epoch, int) or epoch < 0:
            raise FutureEpochError(f"invalid epoch {epoch!r}")
        if epoch > self.epoch:
            raise FutureEpochError(
                f"epoch {epoch} does not exist yet (schedule is at epoch "
                f"{self.epoch}); refusing to verify under a future key")
        return _hkdf_step(self._states[epoch], _SIGN_LABEL)

    def current(self) -> EpochKey:
        """This epoch's key as a first-class credential object."""
        return EpochKey(self.epoch, self.key_for_epoch(self.epoch), self)

    # ----------------------------------------------------------- signing --
    def sign(self, payload: bytes, epoch: int | None = None) -> str:
        """Epoch-bound signature ``"{epoch}:{hexmac}"`` under the current
        (or an explicit existing) epoch key."""
        e = self.epoch if epoch is None else epoch
        mac = hmac.new(self.key_for_epoch(e), payload,
                       hashlib.sha256).hexdigest()
        return f"{e}:{mac}"

    def verify(self, payload: bytes, signature: str) -> bool:
        """Verify an epoch-bound signature.  Old epochs verify after
        rotation; a future epoch raises ``FutureEpochError`` (it is a
        protocol violation, not a mere mismatch)."""
        epoch_s, _, mac = signature.partition(":")
        try:
            epoch = int(epoch_s)
        except ValueError:
            return False
        want = hmac.new(self.key_for_epoch(epoch), payload,
                        hashlib.sha256).hexdigest()
        return hmac.compare_digest(want, mac)

    # --------------------------------------------------------- reporting --
    def describe(self) -> dict:
        return {"epoch": self.epoch,
                "root_fingerprint": fingerprint(self.root)[:16]}


__all__ = ["KeySchedule", "EpochKey"]
