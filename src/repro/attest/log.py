"""Transparency log — an RFC 6962/9162-style Merkle tree over the
registry index.

Every ``RegistryService.publish`` appends one leaf
``(key, manifest_fingerprint, payload_digest, epoch)``; the tree head is
signed per epoch by the service's ``KeySchedule``.  Clients verify

  * INCLUSION: the recording they fetched hashes to a leaf the signed
    root commits to (a silently swapped recording fails here — the log
    says X, the bytes are Y);
  * CONSISTENCY: the new signed root is an append-only extension of the
    root they pinned on a previous fetch (a forked / rewritten log — a
    split view — fails here).

Hashing follows RFC 6962: ``leaf = SHA256(0x00 || data)``,
``node = SHA256(0x01 || left || right)``, and MTH splits at the largest
power of two smaller than n.  Proof generation/verification implement
RFC 9162 §2.1.3 (PATH / inclusion) and §2.1.4 (SUBPROOF / consistency).
Pure data structure: no registry, model, or network imports — the
offline verifier (``repro.attest.verifier``) reuses the verification
half as-is.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional

from repro.core.attest import AttestationError, canonical

# wire-size model for proof billing: each audit-path entry is one 32-byte
# digest; a signed head rides along as root(32) + size(8) + epoch(8) +
# HMAC signature(64, hex-decoded 32 but shipped hex)
PROOF_HASH_BYTES = 32
HEAD_WIRE_BYTES = 112


def leaf_data(key: str, manifest_fp: str, payload_digest: str,
              epoch: int) -> bytes:
    """Canonical byte encoding of one log leaf (strict encoder — the
    same one registry keys fingerprint through)."""
    return canonical({"key": key, "manifest_fp": manifest_fp,
                      "payload_digest": payload_digest, "epoch": epoch})


def leaf_hash(data: bytes) -> bytes:
    return hashlib.sha256(b"\x00" + data).digest()


def node_hash(left: bytes, right: bytes) -> bytes:
    return hashlib.sha256(b"\x01" + left + right).digest()


def _largest_power_of_two_below(n: int) -> int:
    k = 1
    while k * 2 < n:
        k *= 2
    return k


class TransparencyLog:
    """Append-only Merkle tree over raw leaf byte strings."""

    EMPTY_ROOT = hashlib.sha256(b"").hexdigest()

    def __init__(self):
        self._leaves: List[bytes] = []      # leaf HASHES, append order
        self.entries: List[bytes] = []      # raw leaf data, append order

    @property
    def size(self) -> int:
        return len(self._leaves)

    def append(self, data: bytes) -> int:
        """Append one leaf; returns its index."""
        self.entries.append(data)
        self._leaves.append(leaf_hash(data))
        return len(self._leaves) - 1

    # ------------------------------------------------------------ hashing --
    def _mth(self, lo: int, hi: int) -> bytes:
        """Merkle tree hash over leaves [lo, hi) — RFC 6962 §2.1."""
        n = hi - lo
        if n == 1:
            return self._leaves[lo]
        k = _largest_power_of_two_below(n)
        return node_hash(self._mth(lo, lo + k), self._mth(lo + k, hi))

    def root(self, size: Optional[int] = None) -> str:
        """Hex root over the first ``size`` leaves (default: all)."""
        n = self.size if size is None else size
        if not 0 <= n <= self.size:
            raise AttestationError(f"log has {self.size} leaves, "
                                   f"no root at size {n}")
        if n == 0:
            return self.EMPTY_ROOT
        return self._mth(0, n).hex()

    # ------------------------------------------------------------- proofs --
    def inclusion_proof(self, index: int,
                        size: Optional[int] = None) -> List[str]:
        """Audit path for leaf ``index`` in the first ``size`` leaves
        (RFC 9162 §2.1.3.1 PATH), bottom-up, hex digests."""
        n = self.size if size is None else size
        if not 0 <= index < n <= self.size:
            raise AttestationError(
                f"no inclusion proof for index {index} at size {n} "
                f"(log has {self.size} leaves)")
        return [h.hex() for h in self._path(index, 0, n)]

    def _path(self, m: int, lo: int, hi: int) -> List[bytes]:
        n = hi - lo
        if n == 1:
            return []
        k = _largest_power_of_two_below(n)
        if m - lo < k:
            return self._path(m, lo, lo + k) + [self._mth(lo + k, hi)]
        return self._path(m, lo + k, hi) + [self._mth(lo, lo + k)]

    def consistency_proof(self, old_size: int,
                          new_size: Optional[int] = None) -> List[str]:
        """Proof that the first ``new_size`` leaves extend the first
        ``old_size`` (RFC 9162 §2.1.4.1 SUBPROOF), hex digests."""
        n = self.size if new_size is None else new_size
        if not 0 < old_size <= n <= self.size:
            raise AttestationError(
                f"no consistency proof {old_size} -> {n} "
                f"(log has {self.size} leaves)")
        if old_size == n:
            return []
        return [h.hex() for h in self._subproof(old_size, 0, n, True)]

    def _subproof(self, m: int, lo: int, hi: int,
                  whole: bool) -> List[bytes]:
        n = hi - lo
        if m == n:
            return [] if whole else [self._mth(lo, hi)]
        k = _largest_power_of_two_below(n)
        if m <= k:
            return self._subproof(m, lo, lo + k, whole) + \
                [self._mth(lo + k, hi)]
        return self._subproof(m - k, lo + k, hi, False) + \
            [self._mth(lo, lo + k)]


# ----------------------------------------------- stateless verification --
# Pure functions over hex digests: the offline verifier and the clients
# share these; neither needs a TransparencyLog instance.

def verify_inclusion(data: bytes, index: int, size: int, path: List[str],
                     root: str) -> bool:
    """RFC 9162 §2.1.3.2: fold the audit path from ``data``'s leaf hash
    up to the root and compare."""
    if not 0 <= index < size:
        return False
    fn, sn = index, size - 1
    r = leaf_hash(data)
    for p in path:
        sib = bytes.fromhex(p)
        if sn == 0:
            return False
        if fn % 2 == 1 or fn == sn:
            r = node_hash(sib, r)
            while fn % 2 == 0 and fn != 0:
                fn //= 2
                sn //= 2
        else:
            r = node_hash(r, sib)
        fn //= 2
        sn //= 2
    return sn == 0 and r.hex() == root


def verify_consistency(old_size: int, old_root: str, new_size: int,
                       new_root: str, proof: List[str]) -> bool:
    """RFC 9162 §2.1.4.2: the tree at ``new_size`` is an append-only
    extension of the tree at ``old_size``."""
    if old_size > new_size or old_size == 0:
        return False
    if old_size == new_size:
        return not proof and old_root == new_root
    if not proof:
        return False
    hashes = [bytes.fromhex(p) for p in proof]
    fn, sn = old_size - 1, new_size - 1
    while fn % 2 == 1:
        fn //= 2
        sn //= 2
    if fn == 0:             # old tree is a complete subtree: seed with its root
        fr = nr = bytes.fromhex(old_root)
    else:
        fr = nr = hashes[0]
        hashes = hashes[1:]
    for c in hashes:
        if sn == 0:
            return False
        if fn % 2 == 1 or fn == sn:
            fr = node_hash(c, fr)
            nr = node_hash(c, nr)
            while fn % 2 == 0 and fn != 0:
                fn //= 2
                sn //= 2
        else:
            nr = node_hash(nr, c)
        fn //= 2
        sn //= 2
    return sn == 0 and fr.hex() == old_root and nr.hex() == new_root


def proof_wire_bytes(path: List[str], with_head: bool = True) -> int:
    """Deterministic wire-size model for billing a served proof."""
    return PROOF_HASH_BYTES * len(path) + (HEAD_WIRE_BYTES if with_head
                                           else 0)


__all__ = ["TransparencyLog", "leaf_data", "leaf_hash", "node_hash",
           "verify_inclusion", "verify_consistency", "proof_wire_bytes",
           "PROOF_HASH_BYTES", "HEAD_WIRE_BYTES"]
