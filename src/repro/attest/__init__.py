"""repro.attest — transparency log + key rotation + replay attestation.

The end-to-end trust chain over the record -> publish -> fetch -> replay
lifecycle (ROADMAP "attested replay"; SAGE / CT-style design):

    log.py       RFC 6962/9162 Merkle tree over the registry index:
                 signed tree heads, inclusion + consistency proofs
    keys.py      per-epoch signing keys (HKDF-style ratchet), owned by
                 ``Workspace``; old epochs verifiable, future rejected
    quote.py     replay quotes binding (recording key, executable
                 fingerprint, plan fingerprint, commit-frontier digest,
                 signed root)
    verifier.py  OFFLINE quote verification — imports no model/registry
                 code (tested by source scan)

``RegistryService`` appends a leaf per publish and serves proofs;
``RegistryClient`` / ``RegistryReadReplica`` verify inclusion +
consistency before trusting fetched bytes (``SplitViewError`` on a
silently swapped recording or forked log, BEFORE any unpickle).
"""
from repro.core.attest import (AttestationError, FutureEpochError,
                               QuoteVerificationError, RotatedKeyError,
                               SplitViewError)
from repro.attest.keys import EpochKey, KeySchedule
from repro.attest.log import (TransparencyLog, leaf_data, leaf_hash,
                              proof_wire_bytes, verify_consistency,
                              verify_inclusion)
from repro.attest.quote import (BOUND_FIELDS, build_quote,
                                frontier_digest_of, plan_fingerprint_of,
                                quote_signable)
from repro.attest.verifier import head_signable, verify_head, verify_quote

__all__ = [
    "AttestationError", "BOUND_FIELDS", "EpochKey", "FutureEpochError",
    "KeySchedule", "QuoteVerificationError", "RotatedKeyError",
    "SplitViewError", "TransparencyLog", "build_quote",
    "frontier_digest_of", "head_signable", "leaf_data", "leaf_hash",
    "plan_fingerprint_of", "proof_wire_bytes", "quote_signable",
    "verify_consistency", "verify_head", "verify_inclusion",
    "verify_quote",
]
