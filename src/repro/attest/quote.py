"""Replay attestation quotes.

A quote is a replica's signed claim "I replayed THIS recording, through
THIS plan, with THIS observable effect, against THIS published log
view".  Bound fields::

    recording_key     the registry key that was replayed
    exec_fingerprint  fingerprint of the executable payload (== the
                      transparency-log leaf's payload_digest, so the
                      verifier can bind quote -> log leaf offline)
    plan_fingerprint  the compacted replay plan's identity (source
                      fingerprint + pass stack + dispatch structure)
    frontier_digest   digest of the committed write frontier — the
                      replay's observable device effect
    root / log_size   the signed tree head the replica fetched under
    epoch             the key epoch the quote is signed in

``quote_signable`` canonicalizes exactly these fields, so perturbing ANY
one of them invalidates the signature — the offline verifier checks the
whole binding with no model or registry imports.
"""
from __future__ import annotations

from typing import Optional

from repro.core.attest import canonical, fingerprint
from repro.attest.keys import KeySchedule

BOUND_FIELDS = ("recording_key", "exec_fingerprint", "plan_fingerprint",
                "frontier_digest", "root", "log_size", "epoch")


def quote_signable(quote: dict) -> bytes:
    """Canonical bytes of the bound fields (and ONLY those — extra
    annotation keys never enter the signature)."""
    missing = [f for f in BOUND_FIELDS if f not in quote]
    if missing:
        raise ValueError(f"quote is missing bound fields {missing}")
    return canonical({f: quote[f] for f in BOUND_FIELDS})


def build_quote(keys: KeySchedule, *, recording_key: str,
                exec_fingerprint: str, plan_fingerprint: str,
                frontier_digest: str, head: dict,
                annotations: Optional[dict] = None) -> dict:
    """Assemble and sign a quote against a signed tree ``head``
    (``{"size", "root", "epoch", "signature"}`` as served by
    ``RegistryService.signed_head``)."""
    quote = {"recording_key": recording_key,
             "exec_fingerprint": exec_fingerprint,
             "plan_fingerprint": plan_fingerprint,
             "frontier_digest": frontier_digest,
             "root": head["root"], "log_size": int(head["size"]),
             "epoch": keys.epoch}
    if annotations:
        quote.update({k: v for k, v in annotations.items()
                      if k not in BOUND_FIELDS and k != "signature"})
    quote["signature"] = keys.sign(quote_signable(quote))
    return quote


def plan_fingerprint_of(plan) -> str:
    """A ``ReplayPlan``'s identity: the source executable it was derived
    from, the pass stack that compacted it, and the resulting dispatch
    structure (group labels + op counts) — a different compaction of the
    same recording is a DIFFERENT claim."""
    return fingerprint(plan.source_fingerprint, list(plan.passes),
                       plan.jobs,
                       [[g.label, len(g.ops)] for g in plan.groups])


def frontier_digest_of(write_log) -> str:
    """Digest of the committed ``(site, payload)`` write sequence — the
    bit-exactness witness the replay tests already pin, reused as the
    quote's observable-effect binding."""
    return fingerprint([[site, payload] for site, payload in write_log])


__all__ = ["BOUND_FIELDS", "quote_signable", "build_quote",
           "plan_fingerprint_of", "frontier_digest_of"]
