"""Offline quote verifier — the remote-verifier half of attested replay.

Like ``ReplayChannel``'s trust boundary, this module imports NO model,
registry, serving, or record code (a test scans its source): a verifier
needs only the quote, a signed tree head, optionally the recording's log
leaf + inclusion proof, and the shared ``KeySchedule`` — everything a
remote party would hold, nothing a replica could lie about.

Checks, in order (each failure is a distinct ``QuoteVerificationError``):

  1. the signed head verifies under the key schedule (epoch-bound);
  2. the quote's signature covers exactly its bound fields;
  3. the quote binds THIS head (root + log size match);
  4. with ``leaf``/``proof``: the leaf names the quoted recording key and
     executable digest, and its inclusion proof folds up to the head's
     root — the replayed bytes are the published bytes.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.attest import (FutureEpochError, QuoteVerificationError,
                               canonical)
from repro.attest.keys import KeySchedule
from repro.attest.log import leaf_data, verify_inclusion
from repro.attest.quote import quote_signable

HEAD_FIELDS = ("size", "root", "epoch", "signature")


def head_signable(head: dict) -> bytes:
    """Canonical bytes a signed tree head's signature covers."""
    return canonical({"size": int(head["size"]), "root": head["root"]})


def verify_head(head: dict, keys: KeySchedule) -> dict:
    missing = [f for f in HEAD_FIELDS if f not in head]
    if missing:
        raise QuoteVerificationError(f"tree head missing fields {missing}")
    try:
        ok = keys.verify(head_signable(head), head["signature"])
    except FutureEpochError as e:
        raise QuoteVerificationError(f"tree head: {e}")
    if not ok:
        raise QuoteVerificationError(
            f"tree head signature does not verify (size={head['size']}, "
            f"root={head['root'][:12]}...)")
    return head


def verify_quote(quote: dict, *, head: dict, keys: KeySchedule,
                 leaf: Optional[dict] = None,
                 proof: Optional[List[str]] = None,
                 leaf_index: Optional[int] = None) -> dict:
    """Full offline verification; returns a report dict on success,
    raises ``QuoteVerificationError`` on any failed binding."""
    verify_head(head, keys)
    try:
        ok = keys.verify(quote_signable(quote), quote.get("signature", ""))
    except FutureEpochError as e:
        raise QuoteVerificationError(f"quote: {e}")
    except ValueError as e:
        raise QuoteVerificationError(str(e))
    if not ok:
        raise QuoteVerificationError(
            "quote signature does not verify: a bound field was altered "
            "or the quote was signed under a different key schedule")
    if quote["root"] != head["root"] or \
            int(quote["log_size"]) != int(head["size"]):
        raise QuoteVerificationError(
            f"quote binds log view (size={quote['log_size']}, "
            f"root={str(quote['root'])[:12]}...) but the supplied head is "
            f"(size={head['size']}, root={head['root'][:12]}...)")
    checked_inclusion = False
    if leaf is not None:
        if proof is None or leaf_index is None:
            raise QuoteVerificationError(
                "leaf supplied without its inclusion proof/index")
        if leaf.get("key") != quote["recording_key"]:
            raise QuoteVerificationError(
                f"log leaf is for key {leaf.get('key')!r}, quote claims "
                f"{quote['recording_key']!r}")
        if leaf.get("payload_digest") != quote["exec_fingerprint"]:
            raise QuoteVerificationError(
                "quoted executable fingerprint does not match the "
                "published leaf's payload digest: the replica replayed "
                "bytes the log never vouched for")
        data = leaf_data(leaf["key"], leaf["manifest_fp"],
                         leaf["payload_digest"], leaf["epoch"])
        if not verify_inclusion(data, int(leaf_index), int(head["size"]),
                                proof, head["root"]):
            raise QuoteVerificationError(
                f"inclusion proof for leaf {leaf_index} does not fold up "
                f"to the signed root {head['root'][:12]}...")
        checked_inclusion = True
    return {"ok": True, "recording_key": quote["recording_key"],
            "epoch": quote["epoch"], "log_size": int(head["size"]),
            "root": head["root"], "inclusion_checked": checked_inclusion}


__all__ = ["verify_quote", "verify_head", "head_signable", "HEAD_FIELDS"]
