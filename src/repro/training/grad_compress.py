"""Int8 error-feedback gradient compression (distributed-optimization trick).

Two pieces:

1. ``make_ef_int8_transform`` — a ``grad_transform`` hook for train_step:
   grads are quantized to int8 (per-leaf max scaling) with the residual
   carried in an error-feedback buffer (Karimireddy et al. style), so the
   *update math* matches what a compressed-collective deployment computes.

2. ``compressed_psum`` — a shard_map collective that actually moves int8 on
   the wire for the DP all-reduce: quantize -> all_to_all (scatter chunks)
   -> local fp32 sum -> requantize -> all_gather.  Wire bytes per device:
   2 x S x (n-1)/n x 1B  vs  2 x S x (n-1)/n x 4B for fp32 ring AR (4x
   reduction; 2x vs bf16).  Benchmarked in benchmarks/grad_compress.py via
   the HLO analyzer.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _quant(x, axis=None):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def make_ef_int8_transform():
    """grad_transform(grads, state) -> (decompressed_grads, state') with an
    error-feedback buffer stored in state['ef']."""

    def transform(grads, state):
        ef = state.get("ef")
        if ef is None:
            ef = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

        def one(g, e):
            v = g.astype(jnp.float32) + e
            q, s = _quant(v)
            d = _dequant(q, s)
            return d.astype(g.dtype), v - d

        flat_g, td = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = jax.tree.unflatten(td, [o[0] for o in out])
        new_e = jax.tree.unflatten(td, [o[1] for o in out])
        state = dict(state)
        state["ef"] = new_e
        return new_g, state

    return transform


def compressed_psum(x, mesh, axis: str = "data"):
    """int8-on-the-wire all-reduce over `axis` (reduce-scatter + all-gather
    in int8 with fp32 local accumulation)."""
    n = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def inner(xs):
        # xs: local shard [*dims]; reduce over `axis` peers
        flat = xs.reshape(-1)
        pad = (-flat.shape[0]) % n
        flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(n, -1)
        q, s = _quant(chunks)
        # scatter: chunk i goes to rank i (int8 wire)
        qt = jax.lax.all_to_all(q, axis, 0, 0)               # [n, chunk]
        st = jax.lax.all_gather(s, axis)                     # scales
        partial_sum = jnp.sum(_dequant(qt, st[:, None]), axis=0)
        q2, s2 = _quant(partial_sum)
        gathered = jax.lax.all_gather(q2, axis)              # [n, chunk] int8
        s2g = jax.lax.all_gather(s2, axis)
        full = _dequant(gathered, s2g[:, None]).reshape(-1)
        full = full[:xs.size] if pad == 0 else full[:-pad] if pad else full
        return full[:xs.size].reshape(xs.shape)

    spec = P(*[None] * x.ndim)
    return shard_map(inner, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)(x)
