"""Step factories: train_step / prefill_step / decode_step (+ fused k-step
decode, the paper's *register-access deferral* realized as k device steps
per host dispatch).

Every factory returns a pure function suitable for ``jax.jit`` +
``.lower().compile()`` — these are exactly the functions the CODY recorder
serializes into recordings.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_update


def cross_entropy(logits, labels, z_loss: float = 1e-4):
    """fp32 CE over (sharded) vocab + z-loss. labels == -100 are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    mask = labels >= 0
    lab = jnp.where(mask, labels, 0)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1)
    return (ce + zl).sum() / denom


def make_loss_fn(cfg: ModelConfig, rules, remat: str = "full",
                 aux_coef: float = 0.01):
    def loss_fn(master_params, batch):
        params = jax.tree.map(
            lambda p: p.astype(jnp.dtype(cfg.dtype))
            if p.dtype == jnp.float32 and p.ndim > 1 else p, master_params)
        logits, aux = M.forward(params, cfg, batch, rules=rules, remat=remat)
        ce = cross_entropy(logits, batch["labels"])
        loss = ce + aux_coef * aux
        return loss, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, rules, opt: AdamWConfig = AdamWConfig(),
                    remat: str = "full", grad_transform: Optional[Callable] = None):
    """grad_transform: optional hook (e.g. int8 error-feedback compression)."""
    loss_fn = make_loss_fn(cfg, rules, remat)

    def train_step(state, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["master"], batch)
        if grad_transform is not None:
            grads, state = grad_transform(grads, state)
        new_state, om = adamw_update(opt, state, grads)
        if grad_transform is not None and "ef" in state:
            new_state["ef"] = state["ef"]
        metrics = {"loss": loss, **parts, **om}
        return new_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, rules, cache_len: int):
    def prefill_step(params, batch):
        logits, caches = M.prefill(params, cfg, batch, cache_len, rules=rules)
        last = logits[:, -1]
        next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return {"next_tokens": next_tok, "last_logits": last}, caches
    return prefill_step


def make_batched_prefill_step(cfg: ModelConfig, rules, cache_len: int):
    """Grouped-admission prefill (serving): right-padded prompts share ONE
    dispatch; each row's next token is read at its true last position
    (causal attention makes it independent of the padding).  Sound for
    attention families because decode masks cache rows >= pos; recurrent
    families (ssm/hybrid) must use the per-request path."""
    def batched_prefill_step(params, tokens, lengths):
        logits, caches = M.prefill(params, cfg, {"tokens": tokens},
                                   cache_len, rules=rules)
        idx = (lengths - 1).astype(jnp.int32)[:, None, None]
        last = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
        next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return {"next_tokens": next_tok, "last_logits": last}, caches
    return batched_prefill_step


def make_decode_step(cfg: ModelConfig, rules, sample: str = "greedy"):
    def decode_step(params, tokens, pos, caches):
        logits, caches = M.decode_step(params, cfg, tokens, pos, caches,
                                       rules=rules)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, caches
    return decode_step


def make_fused_decode_step(cfg: ModelConfig, rules, k: int,
                           eos_id: int = 2):
    """Deferral: run k decode steps inside ONE executable (lax.scan) — the
    paper's batched register-access commit.  Host round trips drop by k.
    Also the paper's §4.3 polling-loop offload: the EOS 'poll' runs device-
    side; the host receives one commit with (tokens[k], done_mask).
    """
    def fused(params, tokens, pos, caches):
        def body(carry, _):
            toks, p, caches, done = carry
            logits, caches = M.decode_step(params, cfg, toks, p, caches,
                                           rules=rules)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(done, toks, nxt)           # freeze finished seqs
            done = done | (nxt == eos_id)
            p = jnp.where(done, p, p + 1)
            return (nxt, p, caches, done), nxt
        done0 = jnp.zeros(tokens.shape, bool)
        (toks, pos, caches, done), seq = jax.lax.scan(
            body, (tokens, pos, caches, done0), None, length=k)
        return {"tokens": seq.T, "pos": pos, "done": done}, caches
    return fused


def abstract_train_state(cfg: ModelConfig):
    params = M.abstract_params(cfg)
    f32 = lambda: jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return {"step": jax.ShapeDtypeStruct((), jnp.int32), "master": f32(),
            "m": f32(), "v": f32()}


def train_state_axes(cfg: ModelConfig):
    axes = M.param_axes(cfg)
    return {"step": (), "master": axes, "m": axes, "v": axes}
