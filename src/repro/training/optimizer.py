"""AdamW with fp32 master weights — ZeRO-style sharded optimizer state.

Optimizer state (master params, first/second moments) is sharded with the
same 2D (FSDP x TP) specs as the parameters, so at 512 chips the full
fp32 state of a 72B model is ~1.7 GB/chip.  No optax dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return {"step": jnp.zeros((), jnp.int32), "master": master,
            "m": zeros(), "v": zeros()}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, state, grads):
    """Returns (new_state, new_bf16_params_castfn, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return p, m, v

    flat_p, treedef = jax.tree.flatten(state["master"])
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new = {"step": step,
           "master": jax.tree.unflatten(treedef, [o[0] for o in out]),
           "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
           "v": jax.tree.unflatten(treedef, [o[2] for o in out])}
    return new, {"grad_norm": gnorm, "lr": lr}
