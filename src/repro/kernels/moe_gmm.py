"""Grouped expert matmul (Pallas TPU): x [E,C,D] @ w [E,D,F] -> [E,C,F].

Grid (E, nC, nF, nD) with the D (contraction) axis innermost, accumulating
in a VMEM fp32 scratch tile — the MoE hot loop after dispatch.  Block
shapes default to MXU-native 128x128 tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import pl_scratch


def _kernel(x_ref, w_ref, o_ref, acc_sc, *, n_d):
    idd = pl.program_id(3)

    @pl.when(idd == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    acc_sc[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(idd == n_d - 1)
    def _fini():
        o_ref[0] = acc_sc[...].astype(o_ref.dtype)


def moe_gmm(x, w, *, blk_c=128, blk_f=128, blk_d=128, interpret=True):
    E, C, D = x.shape
    F = w.shape[-1]
    blk_c, blk_f, blk_d = min(blk_c, C), min(blk_f, F), min(blk_d, D)
    assert C % blk_c == 0 and F % blk_f == 0 and D % blk_d == 0
    grid = (E, C // blk_c, F // blk_f, D // blk_d)
    return pl.pallas_call(
        functools.partial(_kernel, n_d=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_c, blk_d), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, blk_d, blk_f), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, blk_c, blk_f), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pl_scratch((blk_c, blk_f))],
        interpret=interpret,
    )(x, w)
