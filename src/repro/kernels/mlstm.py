"""mLSTM chunkwise kernel (Pallas TPU): matrix-memory linear attention with
per-head scalar decay, numerator+denominator carried across chunks in VMEM
scratch (grid (B, nc), nc sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import pl_scratch


def _kernel(q_ref, k_ref, v_ref, cf_ref, li_ref, y_ref, h_sc, n_sc, *, n_c):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_sc[...] = jnp.zeros_like(h_sc)
        n_sc[...] = jnp.zeros_like(n_sc)

    q = q_ref[0, 0].astype(jnp.float32)     # [Q, nh, dh]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    cumf = cf_ref[0, 0].astype(jnp.float32)  # [Q, nh]
    li = li_ref[0, 0].astype(jnp.float32)
    Q = q.shape[0]

    scores = jnp.einsum("ihd,jhd->ijh", q, k)
    decay = jnp.exp(cumf[:, None, :] - cumf[None, :, :] + li[None, :, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    lmat = jnp.where((ii >= jj)[..., None], decay, 0.0)
    y_diag = jnp.einsum("ijh,ijh,jhd->ihd", scores, lmat, v)
    n_diag = jnp.einsum("ijh,jhd->ihd", lmat, k)

    h_prev, n_prev = h_sc[...], n_sc[...]
    iw = jnp.exp(cumf)
    y_off = jnp.einsum("ihd,hde,ih->ihe", q, h_prev, iw)
    n_off = jnp.einsum("ihd,hd,ih->ih", q, n_prev, iw)
    n = jnp.einsum("ihd->ih", q * n_diag) + n_off
    y = (y_diag + y_off) / jnp.maximum(jnp.abs(n)[..., None], 1.0)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    wgt = jnp.exp(cumf[-1:, :] - cumf + li)
    kbar = k * wgt[..., None]
    cd = jnp.exp(cumf[-1])
    h_sc[...] = h_prev * cd[:, None, None] + jnp.einsum("jhd,jhe->hde", kbar, v)
    n_sc[...] = n_prev * cd[:, None] + jnp.einsum("jhd->hd", kbar)


def mlstm_chunk_scan(q, k, v, cumf, li, *, interpret=True):
    """Chunked views: q,k,v [B,nc,Q,nh,dh]; cumf,li [B,nc,Q,nh]
    -> y [B,nc,Q,nh,dh] (fp32)."""
    B, nc, Q, nh, dh = q.shape
    kernel = functools.partial(_kernel, n_c=nc)
    spec5 = pl.BlockSpec((1, 1, Q, nh, dh), lambda b, c: (b, c, 0, 0, 0))
    spec4 = pl.BlockSpec((1, 1, Q, nh), lambda b, c: (b, c, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[spec5, spec5, spec5, spec4, spec4],
        out_specs=spec5,
        out_shape=jax.ShapeDtypeStruct((B, nc, Q, nh, dh), jnp.float32),
        scratch_shapes=[pl_scratch((nh, dh, dh)), pl_scratch((nh, dh))],
        interpret=interpret,
    )(q, k, v, cumf, li)
