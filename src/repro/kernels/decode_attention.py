"""Single-token GQA decode attention vs a long KV cache (Pallas TPU).

Grid (B, Hkv, nW): W (cache) blocks iterate innermost, carrying online
softmax state in VMEM scratch.  The q tile is [G, hd] (all G query heads of
one KV group), so the MXU contraction is [G,hd]x[hd,blk] — for G>=8 this
keeps the MXU busy even at batch 1, which is the long-context decode cell's
regime.  VMEM: one [blk_w, hd] K tile + V tile + [G, blk_w] scores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import pl_scratch

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale, blk_w, n_w):
    iw = pl.program_id(2)

    @pl.when(iw == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    length = len_ref[0]
    base = iw * blk_w

    @pl.when(base < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [blk_w, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        slot = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(slot < length, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, -1)
        acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(iw == n_w - 1)
    def _fini():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_sc[...] / denom).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, scale=None,
                     blk_w=256, interpret=True):
    """q [B,H,hd]; caches [B,W,Hkv,hd]; lengths [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    W, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else hd ** -0.5
    blk_w = min(blk_w, W)
    assert W % blk_w == 0
    n_w = W // blk_w
    qg = q.reshape(B, Hkv, G, hd)
    kt = k_cache.transpose(0, 2, 1, 3)               # [B,Hkv,W,hd]
    vt = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, blk_w=blk_w, n_w=n_w)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_w),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, iw: (b,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, iw: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, blk_w, hd), lambda b, h, iw: (b, h, iw, 0)),
            pl.BlockSpec((1, 1, blk_w, hd), lambda b, h, iw: (b, h, iw, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, iw: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[pl_scratch((G,)), pl_scratch((G,)),
                        pl_scratch((G, hd))],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out.reshape(B, H, hd)
