"""Fused RMSNorm (Pallas TPU): one pass, fp32 accumulation in-register.

Grid over row blocks; each block loads [blk, D] once from HBM, computes
mean-square + rsqrt + scale fused, writes once — 2x fewer HBM touches than
the unfused (square->mean->rsqrt->mul) chain when XLA fails to fuse across
the reduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) *
                  s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-5, blk: int = 256, interpret=True):
    """x [..., D]; scale [D]."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    blk = min(blk, R)
    pad = (-R) % blk
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(xf.shape[0] // blk,),
        in_specs=[pl.BlockSpec((blk, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((blk, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:R].reshape(orig_shape)
