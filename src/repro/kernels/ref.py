"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q, k, v, *, causal=True, window=0, scale=None):
    """q [B,Sq,H,hd]; k,v [B,Sk,Hkv,hd] -> [B,Sq,H,hd_v]."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    scale = scale if scale is not None else hd ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    pq = jnp.arange(Sq)[:, None] + (Sk - Sq)   # align ends (q offset)
    pk = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= pq >= pk
    if window:
        mask &= pq - pk < window
    s = jnp.where(mask[None, None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", a.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, scale=None):
    """q [B,H,hd]; caches [B,W,Hkv,hd]; lengths [B] (#valid slots)."""
    B, H, hd = q.shape
    W, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(W)[None] < lengths[:, None]
    s = jnp.where(valid[:, None, None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", a.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, hd).astype(q.dtype)


def rmsnorm(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def mamba_chunk(xbar, B_c, C_c, cum, h_prev):
    """One SSD chunk: xbar [B,Q,nh,P]; B_c,C_c [B,Q,N]; cum [B,Q,nh]
    (cumulative log-decay); h_prev [B,nh,P,N] -> (y [B,Q,nh,P],
    new_state [B,nh,P,N])."""
    Q = xbar.shape[1]
    scores = jnp.einsum("bin,bjn->bij", C_c, B_c)
    decay = jnp.exp(cum[:, :, None] - cum[:, None, :])       # [B,Q,Q,nh]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    lmat = jnp.where(mask[None, :, :, None], decay, 0.0)
    y_diag = jnp.einsum("bij,bijh,bjhp->bihp", scores, lmat, xbar)
    y_off = jnp.einsum("bin,bih,bhpn->bihp", C_c, jnp.exp(cum), h_prev)
    rem = jnp.exp(cum[:, -1:, :] - cum)
    state = h_prev * jnp.exp(cum[:, -1])[:, :, None, None] + \
        jnp.einsum("bjn,bjh,bjhp->bhpn", B_c, rem, xbar)
    return y_diag + y_off, state


def mlstm_chunk(q, k, v, cumf, li, h_prev, n_prev):
    """One mLSTM chunk: q,k,v [B,Q,nh,dh]; cumf,li [B,Q,nh];
    h_prev [B,nh,dh,dh]; n_prev [B,nh,dh] -> (y, new_h, new_n)."""
    Q = q.shape[1]
    scores = jnp.einsum("bihd,bjhd->bijh", q, k)
    decay = jnp.exp(cumf[:, :, None] - cumf[:, None, :] + li[:, None])
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    lmat = jnp.where(mask[None, :, :, None], decay, 0.0)
    y_diag = jnp.einsum("bijh,bijh,bjhd->bihd", scores, lmat, v)
    n_diag = jnp.einsum("bijh,bjhd->bihd", lmat, k)
    iw = jnp.exp(cumf)
    y_off = jnp.einsum("bihd,bhde,bih->bihe", q, h_prev, iw)
    n_off = jnp.einsum("bihd,bhd,bih->bih", q, n_prev, iw)
    n = jnp.einsum("bihd->bih", q * n_diag) + n_off
    y = (y_diag + y_off) / jnp.maximum(jnp.abs(n)[..., None], 1.0)
    wgt = jnp.exp(cumf[:, -1:] - cumf + li)
    kbar = k * wgt[..., None]
    cd = jnp.exp(cumf[:, -1])
    new_h = h_prev * cd[:, :, None, None] + \
        jnp.einsum("bjhd,bjhe->bhde", kbar, v)
    new_n = n_prev * cd[..., None] + jnp.einsum("bjhd->bhd", kbar)
    return y, new_h, new_n


def moe_gmm(x, w):
    """Grouped matmul: x [E,C,D] @ w [E,D,F] -> [E,C,F]."""
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)
