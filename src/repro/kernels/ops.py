"""jit'd public wrappers for all Pallas kernels (the drop-in API).

On CPU (this container) the kernels run in interpret mode for correctness
validation; on TPU set ``interpret=False`` (or REPRO_PALLAS_COMPILE=1).
"""
from __future__ import annotations

import os
from functools import partial

import jax

from repro.kernels import ref  # noqa: F401  (oracles live here)
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_chunk_scan_chunked as _mamba
from repro.kernels.mlstm import mlstm_chunk_scan as _mlstm
from repro.kernels.moe_gmm import moe_gmm as _gmm
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"

flash_attention = jax.jit(
    partial(_flash, interpret=INTERPRET),
    static_argnames=("causal", "window", "scale", "blk_q", "blk_k"))
decode_attention = jax.jit(
    partial(_decode, interpret=INTERPRET),
    static_argnames=("scale", "blk_w"))
rmsnorm = jax.jit(partial(_rmsnorm, interpret=INTERPRET),
                  static_argnames=("eps", "blk"))
moe_gmm = jax.jit(partial(_gmm, interpret=INTERPRET),
                  static_argnames=("blk_c", "blk_f", "blk_d"))
mamba_chunk_scan = jax.jit(partial(_mamba, interpret=INTERPRET))
mlstm_chunk_scan = jax.jit(partial(_mlstm, interpret=INTERPRET))
