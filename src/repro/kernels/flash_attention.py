"""Flash attention (causal / sliding-window, GQA) as a Pallas TPU kernel.

Grid (B*H, nQ, nK) — the innermost K dimension iterates sequentially on
TPU, carrying the online-softmax state (m, l, acc) in VMEM scratch.  Block
shapes are MXU-aligned (multiples of 128 on the contracting/lane dims);
the q block + one k/v block + accumulator bound the VMEM working set to
~(3*blk*hd + blk_q*blk_k)*4 bytes, independent of sequence length.

GQA: the kernel grid runs over Q heads; the k/v index_map folds the head
down to its KV group (h -> h // G), so no repeated KV is materialized.
SWA: fully-masked K blocks are skipped via ``pl.when`` on the block index
(the compiler still schedules them, but no FLOPs/VMEM traffic happen on
TPU for predicated-off bodies).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale, causal, window, blk_q, blk_k, n_k, q_offset):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = iq * blk_q + q_offset
    k_start = ik * blk_k
    # block-level relevance (causal lower-left + SWA band)
    relevant = True
    if causal:
        relevant = jnp.logical_and(
            k_start <= q_start + blk_q - 1, True)
    if window:
        relevant = jnp.logical_and(
            relevant, k_start + blk_k - 1 >= q_start - window + 1)

    @pl.when(relevant)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # [blk_q, hd]
        k = k_ref[0].astype(jnp.float32)            # [blk_k, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [blk_q, blk_k]
        pq = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        pk = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= pq >= pk
        if window:
            mask &= pq - pk < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, -1)
        acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ik == n_k - 1)
    def _fini():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0] = (acc_sc[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    blk_q=128, blk_k=128, interpret=True):
    """q [B,Sq,H,hd]; k,v [B,Sk,Hkv,hd] -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else hd ** -0.5
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, blk_q, Sk, blk_k)
    n_q, n_k = Sq // blk_q, Sk // blk_k
    q_offset = Sk - Sq  # align sequence ends

    # layout: heads become the leading grid axis
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_k=n_k, q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda bh, iq, ik, G=G: (bh // G, ik, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda bh, iq, ik, G=G: (bh // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pl_scratch((blk_q,)),
            pl_scratch((blk_q,)),
            pl_scratch((blk_q, hd)),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


def pl_scratch(shape):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover - interpret fallback
        return pl.MemorySpace.ANY
