"""Mamba2 SSD intra-chunk kernel (Pallas TPU).

Grid (B, nc): one program handles one [Q, ...] chunk — computes the
intra-chunk (masked decay) contribution, the off-diagonal term from the
carried state, and the new chunk state.  The chunk state is carried across
the sequentially-iterated nc grid axis in VMEM scratch (same pattern the
flash kernel uses for online softmax), so the HBM traffic is exactly one
read of x/B/C/decay and one write of y + final state.

Head dim is folded into the chunk program (nh*P lanes); Q and N are the
MXU dims (Q=chunk=256, N=64/128 -> pad N to 128 on real hardware).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import pl_scratch


def _kernel(xbar_ref, b_ref, c_ref, cum_ref, y_ref, st_ref, h_sc, *, n_c):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_sc[...] = jnp.zeros_like(h_sc)

    xbar = xbar_ref[0, 0].astype(jnp.float32)    # [Q, nh, P]
    Bm = b_ref[0, 0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)         # [Q, N]
    cum = cum_ref[0, 0].astype(jnp.float32)      # [Q, nh]
    Q = xbar.shape[0]

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    decay = jnp.exp(cum[:, None, :] - cum[None, :, :])                # [Q,Q,nh]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    lmat = jnp.where((ii >= jj)[..., None], decay, 0.0)
    y_diag = jnp.einsum("ij,ijh,jhp->ihp", scores, lmat, xbar)

    h_prev = h_sc[...]                                                # [nh,P,N]
    y_off = jnp.einsum("in,ih,hpn->ihp", Cm, jnp.exp(cum), h_prev)
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    rem = jnp.exp(cum[-1:, :] - cum)                                  # [Q,nh]
    new_h = h_prev * jnp.exp(cum[-1])[:, None, None] + \
        jnp.einsum("jn,jh,jhp->hpn", Bm, rem, xbar)
    h_sc[...] = new_h

    @pl.when(ic == n_c - 1)
    def _fini():
        st_ref[0] = new_h.astype(st_ref.dtype)


def mamba_chunk_scan(xbar, B_c, C_c, cum, *, interpret=True):
    """xbar [B,S,nh,P]; B_c,C_c [B,S,N]; cum [B,S,nh] (log-decay cumsum,
    RESET per chunk by the caller) ; chunk = caller's reshape unit.
    Returns (y [B,S,nh,P], final_state [B,nh,P,N]).

    The caller passes S = nc*Q with cum already chunk-local (as produced by
    repro.models.ssm).  Grid (B, nc)."""
    B, S, nh, P = xbar.shape
    N = B_c.shape[-1]
    # chunk length: the model uses cfg.ssm.chunk; infer from cum resets is
    # fragile — require the caller to pass chunked views instead:
    raise NotImplementedError("use mamba_chunk_scan_chunked")


def mamba_chunk_scan_chunked(xbar, B_c, C_c, cum, *, interpret=True):
    """Chunked views: xbar [B,nc,Q,nh,P]; B_c,C_c [B,nc,Q,N];
    cum [B,nc,Q,nh] -> (y [B,nc,Q,nh,P], final_state [B,nh,P,N])."""
    B, nc, Q, nh, P = xbar.shape
    N = B_c.shape[-1]
    kernel = functools.partial(_kernel, n_c=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, nh, P), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, nh), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, nh, P), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, nh, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, nh, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, P, N), jnp.float32),
        ],
        scratch_shapes=[pl_scratch((nh, P, N))],
        interpret=interpret,
    )(xbar, B_c, C_c, cum)
    return y, st
