"""Quickstart: train a small LM end-to-end with checkpoints, then resume.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train


if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as ckpt:
        print("=== phase 1: train 40 steps with async checkpoints ===")
        train(["--arch", "qwen2.5-3b", "--steps", "40", "--batch", "8",
               "--seq", "64", "--lr", "3e-3", "--ckpt-dir", ckpt,
               "--ckpt-every", "20", "--log-every", "10"])
        print("\n=== phase 2: crash-resume from the checkpoint, 20 more ===")
        train(["--arch", "qwen2.5-3b", "--steps", "60", "--batch", "8",
               "--seq", "64", "--lr", "3e-3", "--ckpt-dir", ckpt,
               "--resume", "--log-every", "10"])
