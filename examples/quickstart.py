"""Quickstart: the paper's whole lifecycle through ``repro.api`` —
record once in the trusted cloud role (distributed recording session
over emulated wifi), publish into the content-addressed registry, then
boot a TEE replica that serves from verified recordings only.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Workspace

if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as root:
        ws = Workspace(registry=root, key=b"quickstart-key", net="wifi")
        wl = ws.workload("cody-mnist", cache_len=64, block_k=4, batch=2,
                         seq=16)
        for kind in ("prefill", "decode"):      # cloud role: record + publish
            pub = wl.publish(wl.record(kind))
            print(f"published {pub['key']} v{pub['version']} "
                  f"({pub['wire_bytes']/1e3:.1f} kB wire)")
        eng = wl.engine()     # TEE role: fetch-verified, warmed ReplayChannel
        for prompt in ([7] * 16, [11] * 16):
            eng.submit(prompt, max_new=6)
        print("served from verified recordings:", eng.run())
        print("link accounting:", ws.report()["net"])
