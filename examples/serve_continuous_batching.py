"""Continuous-batching serving with the paper's I/O optimizations:

  * fused k-step decode blocks  (register-access deferral + §4.3 offload:
    one host dispatch per k tokens, EOS polled device-side)
  * speculative continuation    (§4.2: dispatch block N+1 before block N's
    done-mask readback, k=3 history confidence, metastate rollback)

Compares speculative vs synchronous engine on the same requests and shows
identical outputs with fewer blocking round trips.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve


if __name__ == "__main__":
    print("=== speculative continuation ON (pipeline depth 4) ===")
    outs_spec, eng_spec = serve(["--arch", "qwen2.5-3b", "--requests", "8",
                                 "--max-new", "24", "--slots", "4",
                                 "--block-k", "8", "--pipeline-depth", "4"])
    print("\n=== speculative continuation OFF (synchronous) ===")
    outs_sync, eng_sync = serve(["--arch", "qwen2.5-3b", "--requests", "8",
                                 "--max-new", "24", "--slots", "4",
                                 "--block-k", "8", "--no-speculate"])
    same = outs_spec == outs_sync
    print(f"\noutputs identical under speculation: {same}")
    print(f"speculative blocks: {eng_spec.stats.get('spec_blocks', 0)} "
          f"(sync fallbacks {eng_spec.stats.get('sync_blocks', 0)}, "
          f"mispredicts {eng_spec.stats.get('mispredicts', 0)})")
    print(f"host syncs: {eng_spec.stats.get('host_syncs', 0)} pipelined vs "
          f"{eng_sync.stats.get('host_syncs', 0)} synchronous")
    assert same
