"""The paper's end-to-end story (CODY) through ``repro.api`` only:

  1. CLOUD — record the workload once (no weights or user data needed:
     abstract shapes only) and publish the SIGNED recordings.
  2. TEE   — the engine boots from the registry: chunked fetch, HMAC
     verified BEFORE any unpickle, no model code / compiler in the TCB —
     and serves a private prompt BIT-EXACTLY vs live execution.
  3. An adversary tampers with the fetched recording -> rejected.
  4. The TEE checks the registry's TRANSPARENCY LOG: the fetched bytes
     are committed under a signed Merkle root (inclusion proof), so even
     a validly-signed swap by a compromised registry is caught.

    PYTHONPATH=src python examples/secure_inference.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Workspace
from repro.core import Recording, TamperedRecordingError

KEY = b"cloud-hsm-key"
SHAPES = dict(cache_len=64, block_k=4, batch=1, seq=16)
SECRET_PROMPT = [11, 22, 33, 44, 55, 66, 77, 88,
                 99, 111, 122, 133, 144, 155, 166, 177]

if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as root:
        ws = Workspace(registry=root, key=KEY, net="wifi")
        wl = ws.workload("qwen2.5-3b", **SHAPES)
        print("=== 1. cloud: record + publish (session over wifi) ===")
        for kind in ("prefill", "decode"):
            wl.publish(wl.record(kind))
        print("=== 2. TEE: fetch-verified replay on private data ===")
        tee = wl.engine(seed=42)        # weights stay private in the TEE
        tee.submit(SECRET_PROMPT, max_new=8)
        private = tee.run()
        live = Workspace().workload("qwen2.5-3b", **SHAPES).engine(seed=42)
        live.submit(SECRET_PROMPT, max_new=8)
        assert live.run() == private, "replay diverged from live execution"
        print(f"generated (privately, bit-exact vs live): {private[0]}")
        print("=== 3. adversary tampers with the recording ===")
        blob = bytearray(wl.fetch("decode"))
        blob[len(blob) // 2] ^= 0xFF
        try:
            Recording.from_bytes(bytes(blob), KEY)
            print("!!! tampering NOT detected")
        except TamperedRecordingError as e:
            print(f"tampering rejected by the TEE: {e}")
        print("=== 4. transparency: fetched bytes are in the signed log ===")
        from repro.attest import leaf_data, verify_inclusion
        from repro.attest.verifier import head_signable
        bundle = ws.service.proof_for(wl.key("decode"))
        head, leaf = bundle["head"], bundle["leaf"]
        assert ws.keys.verify(head_signable(head), head["signature"])
        assert verify_inclusion(
            leaf_data(leaf["key"], leaf["manifest_fp"],
                      leaf["payload_digest"], leaf["epoch"]),
            bundle["index"], head["size"], bundle["path"], head["root"])
        print(f"inclusion proof ok: leaf {bundle['index']} of "
              f"{head['size']} under signed root {head['root'][:16]}...")
