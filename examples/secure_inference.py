"""The paper's end-to-end story (CODY):

  1. CLOUD ROLE — dryrun the workload once: lower + compile + serialize the
     execution plan into a SIGNED recording.  No model weights or user data
     are needed (abstract ShapeDtypeStructs only — §5 'metastate only').
  2. TEE ROLE  — the replayer verifies the signature + hardware fingerprint
     and executes the recording on REAL private inputs.  No model code, no
     framework, no compiler in the TCB.
  3. An adversary tampers with the recording -> the replayer rejects it.

    PYTHONPATH=src python examples/secure_inference.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_shrink
from repro.core.attest import TamperedRecordingError
from repro.core.replay import Replayer
from repro.launch.record import main as record_main
from repro.models import model as M

CLOUD_SIGNING_KEY = b"cloud-hsm-key"


def main():
    arch = "qwen2.5-3b"
    cfg = smoke_shrink(get_config(arch))
    with tempfile.TemporaryDirectory() as d:
        print("=== 1. cloud dryrun service: record prefill + fused decode ===")
        record_main(["--arch", arch, "--out", d, "--key",
                     CLOUD_SIGNING_KEY.decode(), "--cache-len", "96",
                     "--block-k", "8", "--batch", "1", "--seq", "16"])

        print("\n=== 2. client TEE: verify + replay on private data ===")
        tee = Replayer(key=CLOUD_SIGNING_KEY)
        pre = tee.load(os.path.join(d, f"{arch}_prefill.codyrec"))
        dec = tee.load(os.path.join(d, f"{arch}_decode.codyrec"))
        print(f"  loaded recordings; manifest topology "
              f"{tee.manifest(pre)['topology'][:12]}... verified")

        params = M.init_params(cfg, jax.random.PRNGKey(42))  # private weights
        secret_prompt = jnp.array([[11, 22, 33, 44, 55, 66, 77, 88,
                                    99, 111, 122, 133, 144, 155, 166, 177]],
                                  jnp.int32)                 # private input
        out, caches = tee.execute(pre, params, {"tokens": secret_prompt})
        toks = [int(out["next_tokens"][0])]
        pos = jnp.array([16], jnp.int32)
        for _ in range(3):
            blk, caches = tee.execute(dec, params, out["next_tokens"],
                                      pos, caches)
            toks += [int(t) for t in blk["tokens"][0]]
            pos = blk["pos"]
        print(f"  generated (privately): {toks}")
        print(f"  replayer stats: {tee.stats}")

        print("\n=== 3. adversary tampers with the recording ===")
        p = os.path.join(d, f"{arch}_decode.codyrec")
        blob = bytearray(open(p, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        open(p, "wb").write(bytes(blob))
        try:
            Replayer(key=CLOUD_SIGNING_KEY).load(p)
            print("  !!! tampering NOT detected")
        except TamperedRecordingError as e:
            print(f"  tampering rejected by the TEE: {e}")


if __name__ == "__main__":
    main()
