"""Paper Fig. 7 + Table 1 reproduction: recording delays under emulated
networks for Naive / OursM / OursMD / OursMDS.

We cannot run a Mali GPU, so we reproduce the paper's *evaluation
methodology*: each workload is a CPU/GPU interaction trace with the
statistics the paper reports (Table 1: blocking round trips under OursM ==
total register-access commits; MemSync MB naive vs metastate-only; #GPU
jobs), structured into the driver-routine segments of Fig. 8 (init probes /
per-job interrupt handling / power transitions / polling loops), with
register values that are constant across jobs (predictable) except a
nondeterministic LATEST_FLUSH_ID-style register per job (the paper's
documented non-speculatable class).

The four variants then run through OUR engine primitives:
  Naive   — one RTT per register access + full-memory sync per job
  OursM   — one RTT per access + metastate-only delta sync       (§5)
  OursMD  — deferral commits (one RTT per commit)                (§4.1+4.3)
  OursMDS — + history-k speculation (async commits)              (§4.2)

Reported: end-to-end recording delay (virtual time) per network, blocking
round trips, sync MB — against the paper's published numbers.
"""
from __future__ import annotations

import numpy as np

from repro.core.deferral import CommitQueue
from repro.core.netem import CELLULAR, WIFI, NetworkEmulator
from repro.core.speculation import (HistorySpeculator, MispredictError,
                                    SpeculativeRunner)

# Paper Table 1 / Fig. 7 ground truth (OursM round trips; MemSync MB).
PAPER = {
    #  name        jobs  rts_oursm  mem_naive_MB  mem_ours_MB  fig7_wifi_s (naive, ours)
    "mnist":      (23,   2837,      3.07,         0.75,        (52, 18)),
    "alexnet":    (60,   5008,      454.91,       4.22,        (None, None)),
    "mobilenet":  (104,  7307,      37.39,        11.79,       (None, None)),
    "squeezenet": (98,   7373,      41.26,        11.3,        (None, None)),
    "resnet12":   (111,  8326,      151.16,       12.96,       (None, None)),
    "vgg16":      (96,   7662,      1215.23,      10.21,       (423, None)),
}


ACCESSES_PER_COMMIT = 5   # paper: deferral encloses ~3.8-5 accesses/commit


def build_trace(name: str, rng) -> list:
    """Interaction trace: list of (segment, ops); an op is
    (kind, site, value_class, cdep) — cdep marks a control dependency (the
    driver branches on this read -> deferral must commit here, §4.1).
    value_class 'nondet' = LATEST_FLUSH_ID-like (never speculatable)."""
    jobs, rts, _, _, _ = PAPER[name]
    per_job = max(8, (rts - 64) // jobs)
    trace = [("init", [("read", f"probe_{i}", "const", (i % 16) == 15)
                       for i in range(64)])]
    for j in range(jobs):
        ops = []
        ops += [("write", "pwr_on", "const", False),
                ("read", "pwr_status", "const", True)]
        ops += [("write", f"job_cfg{i}", "const", False) for i in range(4)]
        ops += [("write", "job_doorbell", "const", False)]
        ops += [("poll", "flush_poll", "const", True)]    # §4.3 offload
        ops += [("read", "latest_flush_id", "nondet", True)]
        fill = per_job - len(ops) - 3
        ops += [("read", f"irq_aux{i}", "const",
                 (i % ACCESSES_PER_COMMIT) == ACCESSES_PER_COMMIT - 1)
                for i in range(max(fill, 0))]
        ops += [("read", "job_irq_status", "const", True),
                ("write", "job_irq_clear", "const", False),
                ("read", "job_status", "const", True)]
        trace.append((f"job{j}", ops))
    return trace


class FakeGPU:
    def __init__(self, rng):
        self.rng = rng
        self.flush_id = 0

    def channel(self, op):
        if op.kind == "write":
            return None
        if op.kind == "poll":
            return 3
        if "latest_flush_id" in op.site:
            self.flush_id += int(self.rng.integers(0, 3))
            return self.flush_id
        return hash(op.site) % 1000  # stable per-register value


def run_variant(name: str, variant: str, profile) -> dict:
    rng = np.random.default_rng(0)
    jobs, rts_ref, mem_naive, mem_ours, _ = PAPER[name]
    trace = build_trace(name, rng)
    gpu = FakeGPU(rng)
    net = NetworkEmulator(profile)
    q = CommitQueue(gpu.channel, netem=net)
    spec = HistorySpeculator(k=3)
    runner = SpeculativeRunner(q, spec, lambda: 0, lambda s, log: None)

    # memory sync model (per job): naive ships all GPU memory; ours ships
    # metastate only, delta-compressed (~35% further reduction measured on
    # our DeltaSync with repeated job descriptors)
    mem_mb = mem_naive if variant == "naive" else mem_ours
    per_job_bytes = mem_mb * 1e6 / max(jobs, 1)

    recoveries = 0
    log_len = 0

    def commit_point():
        nonlocal recoveries, log_len
        if variant == "oursmd":
            q.commit()
        else:
            runner.commit_speculative()
            if len(runner.outstanding) >= 8:   # validation frontier
                try:
                    runner.sync()
                except MispredictError:
                    # paper §7.3: rollback + replay the interaction log
                    # locally (no network) — 1..3 s depending on log size
                    recoveries += 1
                    net.virtual_time_s += 1.0 + 2.0 * min(log_len / 8000, 1.0)
        log_len += 1

    for seg, ops in trace:
        for kind, site, vclass, cdep in ops:
            if variant in ("naive", "oursm"):
                if kind == "read":
                    q.read(site)
                    q.commit()
                elif kind == "poll":
                    for _ in range(3):   # unoffloaded poll: a few RTTs
                        q.read(site)
                        q.commit()
                else:
                    q.write(site, 1)
                    q.commit()
            else:
                if kind == "read":
                    q.read(site)
                elif kind == "poll":
                    q.poll(site)
                else:
                    q.write(site, 1)
                if cdep:
                    commit_point()
        if variant in ("oursmd", "oursmds"):
            commit_point()
        if seg.startswith("job"):
            net.one_way(int(per_job_bytes))    # memory sync after the job
    if variant == "oursmds":
        try:
            runner.sync()
        except MispredictError:
            recoveries += 1
            net.virtual_time_s += 1.0
    else:
        q.commit()
    return {"workload": name, "variant": variant, "net": profile.name,
            "delay_s": round(net.virtual_time_s, 2),
            "blocking_rts": net.round_trips,
            "async_rts": net.async_trips,
            "sync_MB": round((net.bytes_sent + net.bytes_received) / 1e6, 2),
            "spec_commits": runner.stats.get("spec_commits", 0),
            "mispredicts": recoveries}


def main(quick: bool = False):
    rows = []
    names = ["mnist", "vgg16"] if quick else list(PAPER)
    for name in names:
        for profile in (WIFI, CELLULAR):
            for variant in ("naive", "oursm", "oursmd", "oursmds"):
                rows.append(run_variant(name, variant, profile))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
