"""Record-time ablation (paper Fig. 7 / Table 1): the distributed
recording session under emulated networks, with the three optimization
passes stacked naive -> +deferral -> +speculation -> +metasync
(-> BENCH_recording.json), driven through ``repro.api``.

One REAL cloud dryrun (``Workload.compile``: cody-mnist smoke prefill
through the JAX lower/compile stack) is amortized across all pass stacks
— serialized executables are not byte-deterministic across recompiles,
so sharing the artifact (``Workload.record(artifact=...)``) is what
makes the session-produced recordings comparable to the legacy local
record path at all.  Each stack then runs the full two-party
device<->cloud protocol over the emulated link; the per-stack session
report is read off the manifest the session annotated.

Acceptance (asserted into the JSON):
  * virtual record time strictly decreases down the pass stack on wifi;
  * all passes together cut >= 90% of the naive record time (the paper
    reports "up to 95%");
  * the session-produced recording is byte-identical to the legacy local
    one (same payload/trees, same ``exec_fingerprint``) and verifies
    under the same signing key.
"""
from __future__ import annotations

import json

from repro.api import Workspace
from repro.core.netem import CELLULAR, WIFI
from repro.core.recording import Recording

KEY = b"recording-ablation-key"
JOBS = 32          # pinned GPU job count: the ablation must not drift with
                   # executable size across jax versions
SHAPES = dict(cache_len=64, block_k=4, batch=1, prefill_batch=1, seq=16)

STACKS = [
    ("naive", ()),
    ("+deferral", ("deferral",)),
    ("+speculation", ("deferral", "speculation")),
    ("+metasync", ("deferral", "speculation", "metasync")),
]


def _dryrun_once() -> Recording:
    """The one real compile every session variant replays over the wire."""
    ws = Workspace(key=KEY)
    return ws.workload("cody-mnist", **SHAPES).compile("prefill")


def run_profile(profile, base: Recording) -> list:
    ws = Workspace(key=KEY, net=profile.name, trace=True)
    wl = ws.workload("cody-mnist", **SHAPES)
    rows = []
    for label, passes in STACKS:
        since = ws.tracer.mark()   # per-stack attribution window
        rec = wl.record("prefill", passes=passes, artifact=base, jobs=JOBS)
        rep = rec.manifest["record_session"]
        attributed = ws.tracer.attributed_s("record", since=since)
        attribution = round(attributed / rep["virtual_time_s"], 6) \
            if rep["virtual_time_s"] else 1.0
        spec = rep["per_pass"].get("speculation", {})
        sync_layer = "metasync" if "metasync" in rep["per_pass"] else "wire"
        rows.append({
            "stack": label, "net": profile.name,
            "passes": rep["passes"],
            "virtual_time_s": rep["virtual_time_s"],
            "blocking_rts": rep["blocking_round_trips"],
            "async_rts": rep["async_round_trips"],
            "wire_MB": round((rep["bytes_sent"] + rep["bytes_received"])
                             / 1e6, 3),
            "sync_bytes": int(rep["per_pass"][sync_layer]
                              .get("sync_bytes", 0)),
            "spec_commits": int(spec.get("spec_commits", 0)),
            "mispredicts": int(spec.get("mispredicts", 0)),
            "jobs": rep["jobs"],
            "bit_exact_vs_legacy":
                rec.payload == base.payload and rec.trees == base.trees
                and rec.manifest["exec_fingerprint"]
                == base.manifest["exec_fingerprint"],
            "verifies_under_key": _verifies(rec),
            "record_virtual_s": rec.manifest["record_virtual_s"],
            # fraction of the session's billed virtual time covered by
            # named trace spans (union of intervals — no double counting)
            "trace_attribution": attribution,
        })
    if profile.name == "wifi":
        ws.tracer.dump("TRACE_recording.json")
    return rows


def _verifies(rec: Recording) -> bool:
    signed = Recording(dict(rec.manifest), rec.payload,
                       rec.trees).sign_with(KEY)
    try:
        Recording.from_bytes(signed.to_bytes(), KEY)
        return True
    except Exception:
        return False


def main(quick: bool = False, out_json: str = "BENCH_recording.json"):
    base = _dryrun_once()
    rows = []
    for profile in (WIFI,) if quick else (WIFI, CELLULAR):
        rows.extend(run_profile(profile, base))
    wifi = [r for r in rows if r["net"] == "wifi"]
    times = [r["virtual_time_s"] for r in wifi]
    summary = {
        "rows": rows,
        "record_wall_s": round(base.manifest["record_wall_s"], 3),
        "wifi_virtual_times_s": times,
        "monotone_virtual_time":
            all(a > b for a, b in zip(times, times[1:])),
        "all_passes_reduction_vs_naive":
            round(1.0 - times[-1] / times[0], 4),
        "all_passes_ge_90pct_below_naive": times[-1] <= 0.1 * times[0],
        "bit_exact_vs_legacy": all(r["bit_exact_vs_legacy"] for r in rows),
        "verifies_under_key": all(r["verifies_under_key"] for r in rows),
        # ISSUE-7 acceptance: >= 95% of each wifi session's billed virtual
        # time is attributed to named trace spans
        "trace_attribution": {r["stack"]: r["trace_attribution"]
                              for r in wifi},
        "trace_attributed_ge_95pct":
            all(r["trace_attribution"] >= 0.95 for r in wifi),
    }
    with open(out_json, "w") as f:
        json.dump(summary, f, indent=1)
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
