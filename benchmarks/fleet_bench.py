"""Fleet-scale replay serving benchmark — the ISSUE-8 acceptance artifact.

Boots a fleet of replay replicas from the registry and serves a
deterministic open-loop arrival process (per-tenant Poisson + periodic
bursts) through each placement policy, reporting per-tenant
p50/p99/p99.9 request latency on the fleet's virtual tick clock:

  * ``cold`` — ONE replica records-on-miss (the cold path a fleet pays
    exactly once per key, fleet-wide, thanks to the single-flight lease);
  * one warm fleet per policy (round_robin / least_loaded /
    cache_affinity), every replica booting warm from regional registry
    read-replicas on its own netem billing span;
  * a solo reference run per tenant (same recordings, same params) that
    every fleet-served request is checked bit-exact against.

Acceptance flags pinned by ``repro.obs.schema``:
``bit_exact_vs_solo``, ``warm_boot_cheaper_than_cold``,
``warm_boot_reduction_ge_80pct``.

Determinism: everything in ``BENCH_fleet.json`` is byte-identical across
runs EXCEPT fields whose key mentions ``wall`` or ``boot`` (recording
wall time and serialized-executable payload sizes are not deterministic
across recompiles); ``strip_nondeterministic`` removes exactly those and
is what the same-seed determinism test diffs on.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--quick]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

from repro.api import Workspace
from repro.fleet import OpenLoopTraffic, TenantMix

ARCHS = ("qwen2.5-3b", "xlstm-350m")
CACHE_LEN = 64
BLOCK_K = 4
N_SLOTS = 2
SEQ = 8          # replay prefill pins the prompt shape: every prompt is SEQ
POLICIES = ("round_robin", "least_loaded", "cache_affinity")
REPLICAS = 3
REGIONS = 2
TICK_S = 0.02


def strip_nondeterministic(obj):
    """Drop every dict field whose key mentions ``wall`` or ``boot`` —
    the only fields allowed to differ between same-seed runs."""
    if isinstance(obj, dict):
        return {k: strip_nondeterministic(v) for k, v in obj.items()
                if "wall" not in k and "boot" not in k}
    if isinstance(obj, list):
        return [strip_nondeterministic(v) for v in obj]
    return obj


def _digest(outputs: dict) -> str:
    blob = json.dumps({str(g): list(t) for g, t in sorted(outputs.items())},
                      sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _mixes(wls, quick: bool):
    rates = (10.0, 6.0) if quick else (16.0, 10.0)
    return [TenantMix(wl.cfg.name, rate, prompt_len=SEQ,
                      max_new=(4, 12), vocab=min(wl.cfg.vocab_size, 256))
            for wl, rate in zip(wls, rates)]


def main(quick: bool = False, out_json: str = "BENCH_fleet.json",
         seed: int = 0):
    horizon_s = 1.5 if quick else 4.0
    t_wall = time.time()
    ws = Workspace(registry=":memory:", key=b"fleet-bench", net="wifi")
    wls = [ws.workload(a, cache_len=CACHE_LEN, block_k=BLOCK_K,
                       batch=N_SLOTS, seq=SEQ) for a in ARCHS]
    tenants = [wl.cfg.name for wl in wls]

    traffic = OpenLoopTraffic(_mixes(wls, quick), seed=seed,
                              burst_every_s=1.0, burst_len_s=0.25,
                              burst_x=4.0)
    arrivals = traffic.generate(horizon_s)

    # cold boot: ONE replica records-on-miss through the single-flight
    # lease — after this the registry holds every (tenant, kind) recording
    cold_pool, _ = ws.fleet(wls, replicas=1, policy="round_robin",
                            record_on_miss=True, name="cold",
                            tick_s=TICK_S, seed=seed)
    cold_boot_s = cold_pool.replicas[0].boot_virtual_s

    # warm fleets: one pool per placement policy, same arrival list; each
    # replica boots from its region's read-replica on its own netem span
    policy_rows, fleet_digests = [], {}
    warm_boots = []
    for policy in POLICIES:
        pool, _ = ws.fleet(wls, replicas=REPLICAS, policy=policy,
                           regions=REGIONS, name=policy, tick_s=TICK_S,
                           pending_limit=2 * N_SLOTS, queue_limit=512,
                           seed=seed)
        warm_boots.extend(r.boot_virtual_s for r in pool.replicas)
        t0 = time.time()
        outputs = pool.run(list(arrivals))
        wall = time.time() - t0
        fleet_digests[policy] = _digest(outputs)
        per_tenant = {}
        for tenant in tenants:
            per_tenant[tenant] = {
                "served": sum(1 for a in arrivals
                              if a.tenant == tenant and a.gid in outputs),
                "latency_quantiles": ws.metrics.quantiles(
                    "fleet_request_latency_s", pool=policy, tenant=tenant)
                or {"p50": 0.0, "p99": 0.0, "p999": 0.0},
            }
        policy_rows.append({"policy": policy, "per_tenant": per_tenant,
                            "pool": pool.stats(),
                            "outputs_digest": fleet_digests[policy],
                            "wall_s": round(wall, 3)})

    # solo reference: every arrival served alone through the same
    # recordings and params (stream i uses seed + i, as the fleet does)
    solo = {}
    for i, wl in enumerate(wls):
        eng = wl.engine(seed=seed + i)
        for a in arrivals:
            if a.tenant != wl.cfg.name:
                continue
            rid = eng.submit(list(a.prompt), a.max_new)
            solo[a.gid] = list(eng.run()[rid])
    solo_digest = _digest(solo)

    warm_boot_s = max(warm_boots) if warm_boots else 0.0
    reduction = 100.0 * (1.0 - warm_boot_s / cold_boot_s) \
        if cold_boot_s > 0 else 0.0
    result = {
        "tenants": tenants,
        "shapes": {"cache_len": CACHE_LEN, "block_k": BLOCK_K,
                   "n_slots": N_SLOTS, "seq": SEQ},
        "traffic": {"seed": seed, "horizon_s": horizon_s,
                    "burst_every_s": 1.0, "burst_len_s": 0.25,
                    "burst_x": 4.0, "arrivals": len(arrivals),
                    "rates_rps": [m.rate_rps for m in traffic.mixes]},
        "policies": policy_rows,
        "solo_digest": solo_digest,
        # nondeterministic across runs (recording wall time + payload
        # sizes) — every key here mentions "boot" so the determinism
        # test's strip removes the whole section
        "registry_boot": {
            "cold_boot_virtual_s": round(cold_boot_s, 4),
            "warm_boot_virtual_s": round(warm_boot_s, 4),
            "reduction_pct": round(reduction, 2),
        },
        "bit_exact_vs_solo": all(d == solo_digest
                                 for d in fleet_digests.values()),
        "warm_boot_cheaper_than_cold": warm_boot_s < cold_boot_s,
        "warm_boot_reduction_ge_80pct": reduction >= 80.0,
        "wall_s": round(time.time() - t_wall, 1),
    }
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    rows = []
    for row in policy_rows:
        for tenant, tr in row["per_tenant"].items():
            q = tr["latency_quantiles"]
            rows.append({"policy": row["policy"], "tenant": tenant,
                         "served": tr["served"], "p50": q["p50"],
                         "p99": q["p99"], "p999": q["p999"],
                         "bit_exact": result["bit_exact_vs_solo"]})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for r in main(quick=args.quick):
        print(r)
