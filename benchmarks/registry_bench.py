"""Registry benchmark: cold record vs warm hit vs delta re-record over
emulated networks (-> BENCH_registry.json), driven through ``repro.api``.

Models the CODY fleet economics: the first client to request a key pays
the cloud dryrun (record) plus the full chunked download; every later
client pays only the download (warm hit — zero recording round trips);
a re-record after a config tweak delta-publishes only changed chunks,
and clients holding the old version refetch only the delta.

Acceptance (asserted into the JSON):
  * warm hit: 0 recording round trips, >= 80% lower virtual-time delay
    than cold record on the wifi profile;
  * delta re-record wire bytes measurably below a full publish.
"""
from __future__ import annotations

import json

from repro.api import Workspace
from repro.core.netem import CELLULAR, WIFI
from repro.core.recording import Recording

KEY = b"registry-bench-key"
SHAPES = dict(cache_len=64, block_k=4, batch=1, prefill_batch=1, seq=16)


def _record_once():
    """One real recording (cody-mnist smoke prefill) shared by every
    scenario — made through the API's DISTRIBUTED wifi recording session
    (all passes on), so its manifest carries the realistic record cost
    (compile wall time + session virtual time) that cold fetches bill
    into virtual time.  The bench READS that recorded cost; it never
    recomputes it."""
    ws = Workspace(key=KEY, net="wifi")
    wl = ws.workload("cody-mnist", **SHAPES)
    rec = wl.record("prefill")
    rec.sign_with(KEY)
    return wl.key("prefill"), rec


def _tweaked(rec: Recording) -> Recording:
    """The config-tweak re-record: same executable, updated static meta —
    only manifest + signature parts change."""
    manifest = dict(rec.manifest)
    manifest["static"] = dict(manifest.get("static", {}), revision=2)
    return Recording(manifest, rec.payload, rec.trees).sign_with(KEY)


def run_profile(profile, reg_key: str, rec: Recording) -> list:
    ws = Workspace(registry=":memory:", key=KEY, net=profile.name)
    service = ws.service
    rows = []

    # --- cold: miss -> single-flight record -> publish -> full download --
    net = ws.fresh_netem()
    cold_client = ws.new_client(netem=net)
    record_calls = []
    blob = cold_client.fetch(
        reg_key, record_fn=lambda: record_calls.append(1) or rec)
    rows.append({"scenario": "cold_record", "net": profile.name,
                 "time_s": round(net.virtual_time_s, 4),
                 "recording_round_trips":
                     cold_client.stats["recording_round_trips"],
                 "record_calls": len(record_calls),
                 "bytes_received": net.bytes_received})

    # --- warm: new device, same registry — download only -----------------
    net = ws.fresh_netem()
    warm_client = ws.new_client(netem=net)
    warm_blob = warm_client.fetch(reg_key)
    assert warm_blob == blob
    rows.append({"scenario": "warm_hit", "net": profile.name,
                 "time_s": round(net.virtual_time_s, 4),
                 "recording_round_trips":
                     warm_client.stats["recording_round_trips"],
                 "record_calls": 0,
                 "bytes_received": net.bytes_received})

    # --- delta re-record: config tweak, warm client refetches ------------
    full_stats = service.publish(reg_key + "/fullbase", rec)  # full baseline
    delta_stats = service.publish(reg_key, _tweaked(rec))
    net = ws.fresh_netem()
    warm_client._net = net
    warm_client.fetch(reg_key)       # holds v1 chunks: pulls the delta only
    rows.append({"scenario": "delta_rerecord", "net": profile.name,
                 "time_s": round(net.virtual_time_s, 4),
                 "recording_round_trips": 0,
                 "record_calls": 0,
                 "bytes_received": net.bytes_received,
                 "publish_wire_bytes": delta_stats["wire_bytes"],
                 "full_publish_wire_bytes": full_stats["wire_bytes"],
                 "chunks_reused": delta_stats["chunks_reused"]})
    return rows


def main(quick: bool = False, out_json: str = "BENCH_registry.json"):
    reg_key, rec = _record_once()
    rows = []
    for profile in (WIFI,) if quick else (WIFI, CELLULAR):
        rows.extend(run_profile(profile, reg_key, rec))
    by = {(r["net"], r["scenario"]): r for r in rows}
    cold, warm = by[("wifi", "cold_record")], by[("wifi", "warm_hit")]
    delta = by[("wifi", "delta_rerecord")]
    summary = {
        "rows": rows,
        # recorded cost, READ off the manifest the session populated (the
        # bench never recomputes it): wall compile time + the distributed
        # session's virtual protocol time
        "record_wall_s": round(rec.manifest["record_wall_s"], 3),
        "record_virtual_s": round(rec.manifest["record_virtual_s"], 3),
        "recorded_cost_s": round(rec.manifest["record_wall_s"]
                                 + rec.manifest["record_virtual_s"], 3),
        "wifi_warm_vs_cold_reduction":
            round(1.0 - warm["time_s"] / cold["time_s"], 4),
        "warm_zero_recording_rts": warm["recording_round_trips"] == 0,
        "warm_reduction_ge_80pct":
            warm["time_s"] <= 0.2 * cold["time_s"],
        "delta_wire_lt_full":
            delta["publish_wire_bytes"] < delta["full_publish_wire_bytes"],
        "delta_publish_wire_bytes": delta["publish_wire_bytes"],
        "full_publish_wire_bytes": delta["full_publish_wire_bytes"],
    }
    with open(out_json, "w") as f:
        json.dump(summary, f, indent=1)
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
