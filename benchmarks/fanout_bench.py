"""Multi-device record fan-out ladder (-> BENCH_fanout.json).

A 13-variant wifi campaign (12 prefill seq buckets + decode for the
cody-mnist smoke config) recorded four ways: serially with a cold
speculator per session (today's ``Workload.record`` behavior — the
baseline), then fanned out across 1/2/4/8 devices with the shared
per-hardware-class speculation history.

Every rung replays the SAME 13 compiled artifacts (``Workload.compile``
once per variant, shared via the campaign's artifact dict), and the
FIFO claim rule makes the variant *execution* order identical at every
device count — so per-variant costs match across rungs and the ladder
measures pure virtual-time concurrency.

Acceptance (asserted into the JSON, CI-gated by ``repro.obs.schema``):
  * campaign virtual time strictly monotone decreasing over 1/2/4/8;
  * >= 70% virtual-time reduction at 4 devices vs the serial baseline;
  * every fanned-out recording byte-identical to its serial counterpart
    (payload, trees, exec fingerprint, and the cost-stripped manifest);
  * shared-speculation hit rate >= the cold-per-session baseline,
    computed from the speculator's own predict/hit counters.
"""
from __future__ import annotations

import json

from repro.api import Workspace

KEY = b"fanout-bench-key"
JOBS = 24            # pinned GPU job count per session (determinism across
                     # executable-size drift)
SHAPES = dict(cache_len=64, block_k=4, batch=1, prefill_batch=1)
SEQS = (8, 16, 24, 32, 40, 48, 56, 64, 72, 80, 96, 112)
DEVICE_LADDER = (1, 2, 4, 8)


def _strip_cost(manifest: dict) -> dict:
    """Manifest minus the session-cost annotations (which legitimately
    differ between a cold serial session and a history-warmed one)."""
    return {k: v for k, v in manifest.items()
            if k not in ("record_virtual_s", "record_session")}


def _items(ws, seqs):
    wl = ws.workload("cody-mnist", seq=seqs[0], **SHAPES)
    return wl.variants(seqs=list(seqs), kinds=("prefill", "decode"))


def _run_campaign(devices: int, seqs, artifacts: dict, *,
                  share_history: bool):
    """One fresh-registry campaign rung; returns (recordings, stats)."""
    ws = Workspace(registry=":memory:", key=KEY, net="wifi", trace=True)
    campaign = ws.campaign(_items(ws, seqs), devices=devices, jobs=JOBS,
                           artifacts=artifacts,
                           share_history=share_history,
                           name=f"fanout-d{devices}"
                                f"{'' if share_history else '-cold'}")
    recs = campaign.run()
    return recs, campaign.stats()


def main(quick: bool = False, out_json: str = "BENCH_fanout.json"):
    seqs = SEQS[:4] if quick else SEQS        # quick: 5-variant campaign
    # compile each variant ONCE; every rung and the serial baseline replay
    # these exact artifacts (recordings could not be byte-comparable
    # otherwise — serialization is not deterministic across recompiles)
    artifacts: dict = {}

    # serial baseline: one device, cold speculator per session — exactly
    # the per-variant behavior of today's serial Workload.record loop
    serial_recs, serial_stats = _run_campaign(
        1, seqs, artifacts, share_history=False)
    serial_s = serial_stats["sum_record_virtual_s"]
    cold_hit = serial_stats["speculation"]["hit_rate"]

    ladder = []
    bit_exact = True
    for devices in DEVICE_LADDER:
        recs, stats = _run_campaign(devices, seqs, artifacts,
                                    share_history=True)
        for key, rec in recs.items():
            base = serial_recs[key]
            bit_exact &= (
                rec.payload == base.payload and rec.trees == base.trees
                and rec.manifest["exec_fingerprint"]
                == base.manifest["exec_fingerprint"]
                and _strip_cost(rec.manifest) == _strip_cost(base.manifest))
        ladder.append({
            "devices": devices,
            "virtual_time_s": stats["virtual_time_s"],
            "recorded": stats["recorded"],
            "publishes": stats["publishes"],
            "spec_hit_rate": stats["speculation"]["hit_rate"],
            "blocking_rts": sum(d["blocking_round_trips"]
                                for d in stats["per_device"]),
            "campaign": stats,
        })

    times = [r["virtual_time_s"] for r in ladder]
    by_dev = {r["devices"]: r for r in ladder}
    t4 = by_dev[4]["virtual_time_s"]
    reduction4 = 1.0 - t4 / serial_s
    shared_hit = by_dev[4]["spec_hit_rate"]
    summary = {
        "net": "wifi",
        "variants": len(seqs) + 1,
        "jobs": JOBS,
        "serial": {
            "sessions": serial_stats["recorded"],
            "virtual_time_s": round(serial_s, 6),
            "blocking_rts": sum(d["blocking_round_trips"]
                                for d in serial_stats["per_device"]),
            "campaign": serial_stats,
        },
        "device_ladder": ladder,
        "speculation": {
            "shared_hit_rate": shared_hit,
            "cold_hit_rate": cold_hit,
            # blocking-RTT drop the shared history buys at 4 devices
            "blocking_rts_serial": sum(d["blocking_round_trips"]
                                       for d in serial_stats["per_device"]),
            "blocking_rts_shared": by_dev[4]["blocking_rts"],
        },
        "reduction_at_4_devices_pct": round(100.0 * reduction4, 2),
        "monotone_virtual_time":
            all(a > b for a, b in zip(times, times[1:])),
        "fanout_reduction_ge_70pct": reduction4 >= 0.70,
        "bit_exact_vs_serial": bit_exact,
        "shared_spec_hit_ge_cold": shared_hit >= cold_hit,
    }
    with open(out_json, "w") as f:
        json.dump(summary, f, indent=1)
    rows = [{"devices": 0, "virtual_time_s": round(serial_s, 6),
             "spec_hit_rate": cold_hit, "label": "serial",
             "bit_exact": True}]
    rows += [{"devices": r["devices"],
              "virtual_time_s": r["virtual_time_s"],
              "spec_hit_rate": r["spec_hit_rate"],
              "label": f"{r['devices']}-device",
              "bit_exact": bit_exact} for r in ladder]
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
