"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows per bench plus table sections.
"""
from __future__ import annotations

import argparse
import time


def _section(title):
    print(f"\n# === {title} ===", flush=True)


def _recording_ablation_section(quick: bool):
    _section("Recording session ablation: naive -> +deferral -> "
             "+speculation -> +metasync (-> BENCH_recording.json)")
    from benchmarks import recording_ablation_bench
    for r in recording_ablation_bench.main(quick=quick):
        print(f"recording_{r['stack'].lstrip('+')}_{r['net']},"
              f"{r['virtual_time_s']*1e6:.0f},"
              f"rts={r['blocking_rts']};async={r['async_rts']};"
              f"MB={r['wire_MB']};bit_exact={r['bit_exact_vs_legacy']}")


def _registry_section(quick: bool):
    _section("Registry: cold record vs warm hit vs delta re-record "
             "(-> BENCH_registry.json)")
    from benchmarks import registry_bench
    for r in registry_bench.main(quick=quick):
        print(f"registry_{r['scenario']}_{r['net']},{r['time_s']*1e6:.0f},"
              f"rec_rts={r['recording_round_trips']};"
              f"records={r['record_calls']};rxB={r['bytes_received']}")


def _multitenant_section(quick: bool):
    _section("Multi-tenant: two families, one scheduler "
             "(-> BENCH_multitenant.json)")
    from benchmarks import multitenant_bench
    for r in multitenant_bench.main(quick=quick):
        print(f"multitenant_{r['stream']},{r['wall_s']*1e6:.0f},"
              f"p50={r['p50_latency_s']};spt={r['syncs_per_token']};"
              f"hit={r['spec_hit_rate']}")


def _decode_pipeline_section(quick: bool):
    _section("Decode pipeline: host syncs + tokens/s vs depth "
             "(-> BENCH_decode.json)")
    from benchmarks import decode_pipeline_bench
    for r in decode_pipeline_bench.main(quick=quick):
        print(f"decode_pipeline_d{r['depth']},{r['wall_s']*1e6:.0f},"
              f"tok_s={r['tokens_per_s']};host_syncs={r['host_syncs']};"
              f"rts={r['blocking_round_trips']}")


def _fleet_section(quick: bool):
    _section("Fleet: replica pool + placement policies under open-loop "
             "traffic (-> BENCH_fleet.json)")
    from benchmarks import fleet_bench
    for r in fleet_bench.main(quick=quick):
        print(f"fleet_{r['policy']}_{r['tenant']},{r['p50']*1e6:.0f},"
              f"served={r['served']};p99={r['p99']};p999={r['p999']};"
              f"bit_exact={r['bit_exact']}")


def _fanout_section(quick: bool):
    _section("Record fan-out: device-count ladder + shared speculation "
             "(-> BENCH_fanout.json)")
    from benchmarks import fanout_bench
    for r in fanout_bench.main(quick=quick):
        print(f"fanout_{r['label']},{r['virtual_time_s']*1e6:.0f},"
              f"hit={r['spec_hit_rate']};bit_exact={r['bit_exact']}")


def _attest_section(quick: bool):
    _section("Attestation: proof scaling + verify overhead + split-view "
             "+ quote round-trip (-> BENCH_attest.json)")
    from benchmarks import attest_bench
    for r in attest_bench.main(quick=quick):
        print(f"attest_{r['label']},{r['value']},{r['derived']}")


def _replay_section(quick: bool):
    _section("Replay vs native + replay-plan compaction ablation "
             "(-> BENCH_replay.json)")
    from benchmarks import replay_native
    native_rows, ablation = replay_native.main(quick=quick)
    for r in native_rows:
        print(f"replay_{r['arch']},{r['replay_steady_ms']*1e3:.0f},"
              f"native_ms={r['native_steady_ms']};"
              f"launch_speedup={r['launch_speedup']}x;"
              f"steady_ratio={r['steady_ratio']};"
              f"not_slower={r['replay_not_slower_than_native']}")
    for r in ablation["rows"]:
        print(f"replay_plan_{r['stack'].lstrip('+')},"
              f"{r['total_delay_s']*1e6:.0f},"
              f"rts={r['blocking_rts']};dispatches={r['dispatches']};"
              f"collapsed={r['collapsed_spins']}")
    print(f"# replay ablation: monotone={ablation['monotone_virtual_time']};"
          f"bit_exact_vs_naive={ablation['bit_exact_vs_naive_replay']};"
          f"bit_exact_vs_live={ablation['bit_exact_vs_live']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: decode pipeline + multitenant + registry "
                         "+ recording-ablation + replay + fleet + fanout + "
                         "attest benches only, emit BENCH_decode.json + "
                         "BENCH_multitenant.json + BENCH_registry.json + "
                         "BENCH_recording.json + BENCH_replay.json + "
                         "BENCH_fleet.json + BENCH_fanout.json + "
                         "BENCH_attest.json")
    args = ap.parse_args()
    t0 = time.time()
    print("name,us_per_call,derived")

    if args.smoke:
        _decode_pipeline_section(quick=True)
        _multitenant_section(quick=True)
        _registry_section(quick=True)
        _recording_ablation_section(quick=True)
        _replay_section(quick=True)
        _fleet_section(quick=True)
        _fanout_section(quick=True)
        _attest_section(quick=True)
        print(f"\n# total bench wall time: {time.time()-t0:.1f}s")
        return

    _decode_pipeline_section(quick=args.quick)
    _multitenant_section(quick=args.quick)
    _registry_section(quick=args.quick)
    _recording_ablation_section(quick=args.quick)
    _replay_section(quick=args.quick)
    _fleet_section(quick=args.quick)
    _fanout_section(quick=args.quick)
    _attest_section(quick=args.quick)

    _section("Paper Fig.7 + Table 1: recording delays (emulated networks)")
    from benchmarks import record_replay
    for r in record_replay.main(quick=args.quick):
        print(f"record_{r['workload']}_{r['variant']}_{r['net']},"
              f"{r['delay_s']*1e6:.0f},"
              f"rts={r['blocking_rts']};syncMB={r['sync_MB']};"
              f"mispredicts={r['mispredicts']}")

    _section("Roofline (from dry-run artifacts; single-pod)")
    from benchmarks import roofline
    rows = roofline.main()
    ok = [r for r in rows if r["status"] == "ok"]
    for r in ok:
        print(f"roofline_{r['arch']}_{r['shape']},"
              f"{max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s'])*1e6:.0f},"
              f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
              f"mfu={r['mfu']:.3f};res={r['resident_GiB']}GiB")
    skips = [r for r in rows if r["status"] == "skip"]
    print(f"# roofline: {len(ok)} cells ok, {len(skips)} documented skips")

    _section("Kernels (numerics + jnp-path CPU timing)")
    from benchmarks import kernels_bench
    for r in kernels_bench.main(quick=args.quick):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")

    _section("Gradient compression (collective wire bytes)")
    from benchmarks import grad_compress_bench
    for r in grad_compress_bench.main(quick=args.quick):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")

    print(f"\n# total bench wall time: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
