"""Kernel micro-bench: numerics vs oracle + CPU timing of the jnp reference
path (interpret-mode Pallas timing is meaningless; on TPU flip
REPRO_PALLAS_COMPILE=1 and the same harness times the real kernels)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main(quick: bool = False):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    rows = []

    B, S, H, Hkv, hd = 1, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd)).astype(jnp.bfloat16)
    jref = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v))
    got = ops.flash_attention(q, k, v, blk_q=128, blk_k=128)
    err = float(np.max(np.abs(np.asarray(got, np.float32) -
                              np.asarray(jref(q, k, v), np.float32))))
    rows.append({"name": "flash_attention_512", "us_per_call":
                 round(_time(jref, q, k, v), 1), "derived": f"maxerr={err:.4f}"})

    qd = jax.random.normal(ks[0], (4, H, hd)).astype(jnp.bfloat16)
    kc = jax.random.normal(ks[1], (4, 1024, Hkv, hd)).astype(jnp.bfloat16)
    vc = jax.random.normal(ks[2], (4, 1024, Hkv, hd)).astype(jnp.bfloat16)
    lens = jnp.array([1024, 700, 64, 1], jnp.int32)
    jref2 = jax.jit(lambda q, k, v, l: ref.decode_attention(q, k, v, l))
    err = float(np.max(np.abs(
        np.asarray(ops.decode_attention(qd, kc, vc, lens), np.float32) -
        np.asarray(jref2(qd, kc, vc, lens), np.float32))))
    rows.append({"name": "decode_attention_1k", "us_per_call":
                 round(_time(jref2, qd, kc, vc, lens), 1),
                 "derived": f"maxerr={err:.4f}"})

    x = jax.random.normal(ks[0], (2048, 1024)).astype(jnp.bfloat16)
    sc = jnp.ones((1024,))
    jref3 = jax.jit(lambda x, s: ref.rmsnorm(x, s))
    err = float(np.max(np.abs(np.asarray(ops.rmsnorm(x, sc), np.float32) -
                              np.asarray(jref3(x, sc), np.float32))))
    rows.append({"name": "rmsnorm_2048x1024", "us_per_call":
                 round(_time(jref3, x, sc), 1), "derived": f"maxerr={err:.4f}"})

    xe = jax.random.normal(ks[1], (8, 128, 256)).astype(jnp.bfloat16) * 0.06
    we = jax.random.normal(ks[2], (8, 256, 512)).astype(jnp.bfloat16)
    jref4 = jax.jit(ref.moe_gmm)
    err = float(np.max(np.abs(np.asarray(ops.moe_gmm(xe, we), np.float32) -
                              np.asarray(jref4(xe, we), np.float32))))
    rows.append({"name": "moe_gmm_8x128x256x512", "us_per_call":
                 round(_time(jref4, xe, we), 1), "derived": f"maxerr={err:.4f}"})
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
