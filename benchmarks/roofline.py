"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline source).

Reads artifacts/dryrun2/*.json (written by repro.launch.dryrun) and emits
per (arch x shape x mesh): the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO ratio, roofline fraction, and fit data.
"""
from __future__ import annotations

import glob
import json
import os

DEFAULT_DIR = "artifacts/final"


def load_rows(art_dir: str = DEFAULT_DIR, mesh: str = ""):
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "skip",
                         "reason": r["reason"]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "error"})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "t_compute_s": rf["t_compute_s"], "t_memory_s": rf["t_memory_s"],
            "t_collective_s": rf["t_collective_s"],
            "dominant": rf["dominant"],
            "roofline_fraction": rf["roofline_fraction"],
            "model_flops_ratio": rf["model_flops_ratio"],
            "mfu": rf["mfu"],
            "resident_GiB": round(r.get("resident_bytes",
                                        r["bytes_per_device"]) / 2**30, 2),
            "xla_mem_GiB": round(r["bytes_per_device"] / 2**30, 2),
        })
    return rows


def main(art_dir: str = DEFAULT_DIR):
    rows = load_rows(art_dir, mesh="16x16")
    ok = [r for r in rows if r["status"] == "ok"]
    ok.sort(key=lambda r: r["roofline_fraction"])
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
