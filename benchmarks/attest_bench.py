"""Attestation benchmark: transparency-log proof scaling, verification
overhead, split-view detection, and quote round-trip (-> BENCH_attest.json).

Four claims, asserted into the JSON as acceptance flags:

  * proof size is O(log n): across a 1 -> 64 published-entry ladder the
    longest inclusion audit path never exceeds ceil(log2 n) hashes
    (``proof_growth_sublinear``);
  * proof verification is cheap: a warm wifi fetch with inclusion +
    consistency verification on costs <= 5% more virtual time than the
    same fetch with verification off (``verify_overhead_le_5pct``);
  * a forked registry is caught: swapping a published recording for a
    different validly-signed one raises ``SplitViewError`` before the
    blob is ever returned (``split_view_detected``);
  * quotes bind what ran: a replay quote verifies offline through
    ``repro.attest.verifier`` — which imports no model/registry code
    (``offline_verifier_no_model_imports``) — and perturbing ANY bound
    field is rejected.
"""
from __future__ import annotations

import json
import math
import pickle

import numpy as np

from repro.api import Workspace
from repro.attest import KeySchedule, verify_quote
from repro.attest.log import proof_wire_bytes
from repro.core.attest import (QuoteVerificationError, SplitViewError,
                               fingerprint)
from repro.core.recording import Recording
from repro.core.replay_passes import PlanExecutor, verified_plan

KEY = b"attest-bench-key"
LADDER = (1, 2, 4, 8, 16, 32, 64)

# the offline verifier must hold nothing a replica could lie about —
# and import nothing that could (same boundary test_replay pins for the
# replayer itself)
FORBIDDEN_VERIFIER_IMPORTS = ("repro.models", "repro.configs",
                              "repro.training", "repro.serving",
                              "repro.registry", "repro.record", "jax")


def synthetic_recording(payload_bytes: int = 120_000, seed: int = 0,
                        name: str = "synthetic") -> Recording:
    """A signed recording with random payload — big enough to chunk,
    cheap enough to publish 64 of.  ``exec_fingerprint`` is set so
    ``verified_plan`` accepts it."""
    rng = np.random.default_rng(seed)
    payload = rng.bytes(payload_bytes)
    manifest = {"name": name, "static": {}, "record_wall_s": 2.0,
                "exec_fingerprint": fingerprint(payload)}
    return Recording(manifest, payload,
                     pickle.dumps((None, None))).sign_with(KEY)


def proof_ladder() -> list:
    """Publish 64 entries; at each rung report the WORST-case inclusion
    audit path over every leaf, against the ceil(log2 n) bound."""
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    svc = ws.service
    rows = []
    published = 0
    for n in LADDER:
        while published < n:
            svc.publish(f"ladder/e{published}",
                        synthetic_recording(4_000, seed=published,
                                            name=f"e{published}"))
            published += 1
        worst = max(len(svc.log.inclusion_proof(i, n)) for i in range(n))
        rows.append({"entries": n, "proof_hashes": worst,
                     "proof_wire_bytes": proof_wire_bytes(["x" * 64] * worst),
                     "log2_bound": math.ceil(math.log2(n)) if n > 1 else 0})
    return rows


def verify_overhead() -> dict:
    """Warm wifi fetch, verification on vs off — fresh netem per arm so
    the spans never alias.  Proof bytes ride the async billing path (no
    blocking round trip), so the overhead is bandwidth-only."""
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    reg_key = "overhead/prefill"
    ws.service.publish(reg_key, synthetic_recording())
    # prime: first fetch pays any record-side costs; both arms then warm
    ws.new_client(netem=ws.fresh_netem()).fetch(reg_key)

    net_off = ws.fresh_netem()
    ws.new_client(netem=net_off, verify_proofs=False).fetch(reg_key)
    net_on = ws.fresh_netem()
    cl = ws.new_client(netem=net_on, verify_proofs=True)
    cl.fetch(reg_key)
    t_off, t_on = net_off.virtual_time_s, net_on.virtual_time_s
    return {"warm_fetch_unverified_s": round(t_off, 6),
            "warm_fetch_verified_s": round(t_on, 6),
            "overhead_pct": round(100.0 * (t_on - t_off) / t_off, 3),
            "proof_bytes": int(cl.stats["proof_bytes"]),
            "proofs_verified": int(cl.stats["proofs_verified"])}


def split_view() -> dict:
    """The attack the log exists for: after publish, the registry swaps
    in a DIFFERENT validly-signed recording under the same key.  HMAC
    passes; the transparency log does not — the client must raise
    ``SplitViewError`` instead of returning the swapped bytes."""
    from repro.registry.service import recording_to_parts
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    reg_key = "victim/prefill"
    ws.service.publish(reg_key, synthetic_recording(seed=1))
    old_meta = ws.store.entry(reg_key)["meta"]
    evil = synthetic_recording(seed=2, name="evil")  # signed, wrong bytes
    ws.store.put(reg_key, recording_to_parts(evil, ws.store.chunk_size),
                 meta=old_meta)
    try:
        ws.new_client(netem=ws.fresh_netem()).fetch(reg_key)
        return {"detected": False, "error": None}
    except SplitViewError as e:
        return {"detected": True, "error": str(e)[:120]}


def quote_roundtrip() -> dict:
    """Replay through a verified plan, quote it, verify the quote fully
    offline; then perturb each bound field in turn — every perturbation
    must be rejected."""
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    reg_key = "quoted/prefill"
    ws.service.publish(reg_key, synthetic_recording(seed=3))
    blob = ws.client.fetch(reg_key)
    plan, _rec = verified_plan(blob, KEY, "all", jobs=8)
    ex = PlanExecutor(netem=ws.fresh_netem())
    ex.run(plan)
    head = ws.service.signed_head()
    quote = ex.quote(ws.keys, recording_key=reg_key, head=head)
    bundle = ws.service.proof_for(reg_key)

    offline = KeySchedule(KEY)   # remote party: shared root secret only
    report = verify_quote(quote, head=head, keys=offline,
                          leaf=bundle["leaf"], proof=bundle["path"],
                          leaf_index=bundle["index"])

    from repro.attest.quote import BOUND_FIELDS
    rejected = []
    for field in BOUND_FIELDS:
        bad = dict(quote)
        bad[field] = 999 if isinstance(quote[field], int) \
            else quote[field] + "x" if isinstance(quote[field], str) \
            else "tampered"
        try:
            verify_quote(bad, head=head, keys=offline, leaf=bundle["leaf"],
                         proof=bundle["path"], leaf_index=bundle["index"])
        except QuoteVerificationError:
            rejected.append(field)
    return {"bound_fields": list(BOUND_FIELDS),
            "perturbations_rejected": rejected,
            "inclusion_checked": report["inclusion_checked"],
            "epoch": report["epoch"]}


def verifier_is_model_free() -> bool:
    import repro.attest.verifier as V
    src = open(V.__file__).read()
    return not any(f"import {m}" in src or f"from {m}" in src
                   for m in FORBIDDEN_VERIFIER_IMPORTS)


def main(quick: bool = False, out_json: str = "BENCH_attest.json"):
    ladder = proof_ladder()
    overhead = verify_overhead()
    sview = split_view()
    quote = quote_roundtrip()
    offline_clean = verifier_is_model_free()
    summary = {
        "proof_ladder": ladder,
        "verify_overhead": overhead,
        "split_view": sview,
        "quote": quote,
        "proof_growth_sublinear":
            all(r["proof_hashes"] <= r["log2_bound"] for r in ladder),
        "verify_overhead_le_5pct": overhead["overhead_pct"] <= 5.0,
        "split_view_detected": sview["detected"],
        "quote_all_perturbations_rejected":
            quote["perturbations_rejected"] == quote["bound_fields"],
        "offline_verifier_no_model_imports": offline_clean,
    }
    with open(out_json, "w") as f:
        json.dump(summary, f, indent=1)
    rows = [{"label": f"proof_n{r['entries']}",
             "value": r["proof_hashes"],
             "derived": f"wireB={r['proof_wire_bytes']};"
                        f"bound={r['log2_bound']}"} for r in ladder]
    rows.append({"label": "verify_overhead",
                 "value": overhead["overhead_pct"],
                 "derived": f"proofB={overhead['proof_bytes']};"
                            f"le_5pct={summary['verify_overhead_le_5pct']}"})
    rows.append({"label": "split_view", "value": int(sview["detected"]),
                 "derived": "detected" if sview["detected"] else "MISSED"})
    rows.append({"label": "quote", "value":
                 len(quote["perturbations_rejected"]),
                 "derived": f"bound={len(quote['bound_fields'])};"
                            f"offline_clean={offline_clean}"})
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
