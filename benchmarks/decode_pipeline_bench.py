"""Decode pipeline benchmark — the ISSUE-1 acceptance artifact.

Serves the same workload at pipeline depths {1, 2, 4, 8} and reports
tokens/s plus HOST-SYNC counts: with the async pipeline the host↔device
round trips drop from O(1/block_k) per token (one readback per fused
block) to O(1/(block_k·depth)) (one metastate readback per frontier).
A second section serves the SAME workload once live-jit and once through
verified registry replay (record-on-miss, fast-path dispatch) and
compares tokens/s at unchanged output digests.  Results are written to
``BENCH_decode.json`` so CI tracks the perf trajectory.

    PYTHONPATH=src python -m benchmarks.decode_pipeline_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.api import Workspace
from repro.configs import get_config, smoke_shrink
from repro.core.netem import WIFI, NetworkEmulator
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, cache_batch_axes_for
from repro.sharding import rules_for
from repro.training import steps as ST

DEPTHS = (1, 2, 4, 8)
BLOCK_K = 4
CACHE_LEN = 128
N_SLOTS = 4


def _build_fns(cfg):
    """Jitted steps built ONCE and shared across engines so every depth
    pays identical (zero, after warm-up) compile cost."""
    rules = rules_for("serve", make_host_mesh(model=1).axis_names)
    prefill = jax.jit(ST.make_prefill_step(cfg, rules, CACHE_LEN))
    batched_prefill = jax.jit(
        ST.make_batched_prefill_step(cfg, rules, CACHE_LEN))
    decode = jax.jit(
        ST.make_fused_decode_step(cfg, rules, k=BLOCK_K, eos_id=2),
        donate_argnums=(3,))
    return prefill, batched_prefill, decode


def _run_once(cfg, params, fns, depth, *, requests, max_new, speculate=True):
    prefill, batched_prefill, decode = fns
    net = NetworkEmulator(WIFI)
    eng = Engine(params, prefill, decode, n_slots=N_SLOTS,
                 cache_len=CACHE_LEN, block_k=BLOCK_K, eos_id=2,
                 init_caches_fn=lambda: M.init_cache(cfg, N_SLOTS,
                                                     CACHE_LEN),
                 cache_batch_axes=cache_batch_axes_for(cfg), netem=net,
                 speculate=speculate, pipeline_depth=depth,
                 batched_prefill_fn=batched_prefill)
    rng = np.random.default_rng(0)
    for _ in range(requests):
        plen = int(rng.integers(4, 16))
        eng.submit(list(rng.integers(3, cfg.vocab_size, plen)), max_new)
    t0 = time.time()
    outs = eng.run()
    wall_s = time.time() - t0
    toks = sum(len(v) for v in outs.values())
    virtual_s = net.virtual_time_s
    return {
        "depth": depth,
        "tokens": toks,
        "wall_s": round(wall_s, 4),
        "tokens_per_s_wall": round(toks / wall_s, 1),
        "virtual_net_s": round(virtual_s, 4),
        "tokens_per_s": round(toks / (wall_s + virtual_s), 1),
        "host_syncs": int(eng.stats["host_syncs"]),
        "host_syncs_per_token": round(eng.stats["host_syncs"] / toks, 4),
        "blocking_round_trips": net.round_trips,
        "async_trips": net.async_trips,
        "blocks_dispatched": int(eng.stats["blocks_dispatched"]),
        "spec_blocks": int(eng.stats["spec_blocks"]),
        "mispredicts": int(eng.stats["mispredicts"]),
        "outputs_digest": hash(tuple(tuple(v) for _, v in
                                     sorted(outs.items()))) & 0xFFFFFFFF,
    }


def _serve_once(wl, eng, *, requests, max_new, seed=7):
    """Submit a fixed-length workload and drain — prompt length is pinned
    to the workload's prefill seq so the same requests serve through a
    recorded executable (fixed prompt shape) and live jit alike."""
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(3, wl.cfg.vocab_size, wl.seq))
               for _ in range(requests)]
    for p in prompts:
        eng.submit(p, max_new)
    t0 = time.time()
    outs = eng.run()
    wall_s = time.time() - t0
    toks = sum(len(v) for v in outs.values())
    digest = hash(tuple(tuple(v) for _, v in sorted(outs.items()))) \
        & 0xFFFFFFFF
    return toks, wall_s, digest


def replay_vs_live(quick: bool = False, arch: str = "qwen2.5-3b") -> dict:
    """Live-jit vs verified-registry-replay tokens/s at identical output
    digests: one workload shape, fixed-length prompts, the replay side
    boots record-on-miss and decodes on the Replayer fast path."""
    shapes = dict(cache_len=CACHE_LEN, block_k=BLOCK_K, batch=N_SLOTS,
                  prefill_batch=1, seq=8)
    requests = 4 if quick else 8
    max_new = 16 if quick else 32
    rows = {}
    for mode in ("live", "replay"):
        ws = Workspace() if mode == "live" else \
            Workspace(registry=":memory:", key=b"decode-bench-key")
        wl = ws.workload(arch, **shapes)
        eng = wl.engine(record_on_miss=(mode == "replay"),
                        pipeline_depth=2)
        # warm-up drain compiles/validates every shape, then the timed run
        _serve_once(wl, eng, requests=requests, max_new=max_new, seed=3)
        toks, wall_s, digest = _serve_once(wl, eng, requests=requests,
                                           max_new=max_new)
        row = {"tokens": toks, "wall_s": round(wall_s, 4),
               "tokens_per_s": round(toks / wall_s, 1),
               "outputs_digest": digest}
        if mode == "replay":
            stats = ws.report()["replayer_stats"]
            row["fast_hits"] = int(stats.get("fast_hits", 0))
            row["slow_validations"] = int(stats.get("slow_validations", 0))
        rows[mode] = row
    return {
        "requests": requests, "max_new": max_new, "seq": shapes["seq"],
        "live": rows["live"], "replay": rows["replay"],
        "identical_outputs":
            rows["live"]["outputs_digest"] == rows["replay"]["outputs_digest"],
        "replay_to_live_ratio": round(rows["replay"]["tokens_per_s"]
                                      / rows["live"]["tokens_per_s"], 3),
    }


def main(quick: bool = False, arch: str = "qwen2.5-3b",
         out_json: str = "BENCH_decode.json"):
    cfg = smoke_shrink(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    fns = _build_fns(cfg)
    requests = 6 if quick else 12
    max_new = 32 if quick else 48
    # warm-up: compile every shape the timed runs will hit
    _run_once(cfg, params, fns, 2, requests=requests, max_new=max_new)
    rows = [_run_once(cfg, params, fns, d, requests=requests,
                      max_new=max_new) for d in DEPTHS]
    digests = {r["outputs_digest"] for r in rows}
    result = {"arch": cfg.name, "block_k": BLOCK_K, "n_slots": N_SLOTS,
              "requests": requests, "max_new": max_new,
              "identical_streams_across_depths": len(digests) == 1,
              "depths": rows,
              "replay_vs_live": replay_vs_live(quick, arch)}
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for r in main(quick=args.quick):
        print(r)
