"""Multi-tenant serving benchmark — the ISSUE-3 acceptance artifact,
driven through ``repro.api``.

Serves an attention family (speculating) and a recurrent ssm family
(speculation gated off) first ALONE (``Workload.engine``), then
CONCURRENTLY through one Scheduler (``Workspace.scheduler``), and
reports per-stream p50 request latency, speculation hit rate, and
frontier syncs per token.  The acceptance bar: under multi-tenancy the
frontier remains the only host<->device sync point — each stream's
syncs-per-token is no worse than its single-tenant run — and the token
streams are bit-exact across the two modes (the workload memoizes its
live channel and params, so solo and multi runs share the exact same
compiled step functions and weights).  Results land in
``BENCH_multitenant.json`` so CI tracks the trajectory.

    PYTHONPATH=src python -m benchmarks.multitenant_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.api import Workspace

BLOCK_K = 4
CACHE_LEN = 128
N_SLOTS = 4
ARCHS = ("qwen2.5-3b", "xlstm-350m")


def _prompts(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(3, cfg.vocab_size, int(rng.integers(4, 16))))
            for _ in range(n)]


def _stream_row(name, ex, outs, wall_s):
    toks = sum(len(v) for v in outs.values())
    lat = sorted(r.finish_t - r.submit_t for r in ex.requests.values()
                 if r.done)
    blocks = int(ex.stats["spec_blocks"] + ex.stats["sync_blocks"])
    # per-stream latency quantiles from the metrics layer (the executor
    # observes request_latency_s{stream=...} at every retire)
    quant = (ex.metrics.quantiles("request_latency_s", stream=ex.name)
             if ex.metrics is not None else None)
    return {
        "stream": name,
        "tokens": toks,
        "wall_s": round(wall_s, 4),
        "p50_latency_s": round(lat[len(lat) // 2], 4) if lat else None,
        "latency_quantiles": quant or {"p50": 0.0, "p99": 0.0, "p999": 0.0},
        "host_syncs": int(ex.stats["host_syncs"]),
        "syncs_per_token": round(ex.stats["host_syncs"] / toks, 4),
        "spec_hit_rate": round(ex.stats["spec_blocks"] / blocks, 4)
        if blocks else 0.0,
        "spec_blocks": int(ex.stats["spec_blocks"]),
        "mispredicts": int(ex.stats["mispredicts"]),
        "blocks_dispatched": int(ex.stats["blocks_dispatched"]),
        "outputs_digest": hash(tuple(tuple(v) for _, v in
                                     sorted(outs.items()))) & 0xFFFFFFFF,
    }


def main(quick: bool = False, out_json: str = "BENCH_multitenant.json",
         out_trace: str = "TRACE_multitenant.json"):
    requests = 4 if quick else 8
    max_new = 16 if quick else 32
    ws = Workspace(trace=True)
    wls = {arch: ws.workload(arch, cache_len=CACHE_LEN, block_k=BLOCK_K,
                             batch=N_SLOTS) for arch in ARCHS}
    prompts = {arch: _prompts(wls[arch].cfg, requests, 100 + i)
               for i, arch in enumerate(ARCHS)}

    # warm-up: compile every shape both modes will hit (channels and
    # params are memoized on the workloads, so this pays all jit cost)
    for i, arch in enumerate(ARCHS):
        eng = wls[arch].engine(seed=i)
        for p in prompts[arch]:
            eng.submit(p, max_new)
        eng.run()

    solo_rows = {}
    for i, arch in enumerate(ARCHS):
        eng = wls[arch].engine(seed=i)
        for p in prompts[arch]:
            eng.submit(p, max_new)
        t0 = time.time()
        outs = eng.run()
        solo_rows[arch] = _stream_row(arch, eng.stream, outs,
                                      time.time() - t0)

    # multi-tenant: same channels, same params (seed 0 + stream index);
    # streams register under the (smoke-shrunk) config name
    sched, _ = ws.scheduler(streams=[wls[a] for a in ARCHS], seed=0)
    names = {arch: wls[arch].cfg.name for arch in ARCHS}
    for arch in ARCHS:
        for p in prompts[arch]:
            sched.submit(names[arch], p, max_new)
    t0 = time.time()
    outs = sched.run()
    multi_wall = time.time() - t0
    multi_rows = {arch: _stream_row(arch, sched.streams[names[arch]],
                                    outs[names[arch]], multi_wall)
                  for arch in ARCHS}

    result = {
        "archs": list(ARCHS), "block_k": BLOCK_K, "n_slots": N_SLOTS,
        "requests_per_stream": requests, "max_new": max_new,
        "solo": list(solo_rows.values()),
        "multi": list(multi_rows.values()),
        "frontier": dict(sched.frontier.stats),
        "scheduler": sched.stats(),
        # acceptance: multi-tenancy adds no host syncs and changes no token
        "bit_exact_vs_solo": all(
            multi_rows[a]["outputs_digest"] == solo_rows[a]["outputs_digest"]
            for a in ARCHS),
        "frontier_only_syncs": all(
            multi_rows[a]["syncs_per_token"] <= solo_rows[a]["syncs_per_token"]
            for a in ARCHS),
    }
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    if out_trace:
        ws.tracer.dump(out_trace)
    return [*result["solo"], *[{**r, "stream": r["stream"] + "+mt"}
                               for r in result["multi"]]]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for r in main(quick=args.quick):
        print(r)
