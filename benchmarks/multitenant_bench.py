"""Multi-tenant serving benchmark — the ISSUE-3 acceptance artifact.

Serves an attention family (speculating) and a recurrent ssm family
(speculation gated off) first ALONE, then CONCURRENTLY through one
Scheduler, and reports per-stream p50 request latency, speculation hit
rate, and frontier syncs per token.  The acceptance bar: under
multi-tenancy the frontier remains the only host<->device sync point —
each stream's syncs-per-token is no worse than its single-tenant run —
and the token streams are bit-exact across the two modes.  Results land
in ``BENCH_multitenant.json`` so CI tracks the trajectory.

    PYTHONPATH=src python -m benchmarks.multitenant_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_shrink
from repro.core.channel import LiveChannel
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import stream_kwargs
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler
from repro.sharding import rules_for
from repro.training import steps as ST

BLOCK_K = 4
CACHE_LEN = 128
N_SLOTS = 4
ARCHS = ("qwen2.5-3b", "xlstm-350m")


def _family(arch, seed):
    cfg = smoke_shrink(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    rules = rules_for("serve", make_host_mesh(model=1).axis_names)
    prefill = jax.jit(ST.make_prefill_step(cfg, rules, CACHE_LEN))
    batched = None
    if cfg.family in ("dense", "moe") and not cfg.sliding_window:
        batched = jax.jit(ST.make_batched_prefill_step(cfg, rules, CACHE_LEN))
    decode = jax.jit(
        ST.make_fused_decode_step(cfg, rules, k=BLOCK_K, eos_id=2),
        donate_argnums=(3,))
    channel = LiveChannel(prefill, decode, batched)
    kw = stream_kwargs(cfg, n_slots=N_SLOTS, cache_len=CACHE_LEN,
                       block_k=BLOCK_K, eos_id=2, pipeline_depth=4)
    return cfg, params, channel, kw


def _prompts(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(3, cfg.vocab_size, int(rng.integers(4, 16))))
            for _ in range(n)]


def _stream_row(name, ex, outs, wall_s):
    toks = sum(len(v) for v in outs.values())
    lat = sorted(r.finish_t - r.submit_t for r in ex.requests.values()
                 if r.done)
    blocks = int(ex.stats["spec_blocks"] + ex.stats["sync_blocks"])
    return {
        "stream": name,
        "tokens": toks,
        "wall_s": round(wall_s, 4),
        "p50_latency_s": round(lat[len(lat) // 2], 4) if lat else None,
        "host_syncs": int(ex.stats["host_syncs"]),
        "syncs_per_token": round(ex.stats["host_syncs"] / toks, 4),
        "spec_hit_rate": round(ex.stats["spec_blocks"] / blocks, 4)
        if blocks else 0.0,
        "spec_blocks": int(ex.stats["spec_blocks"]),
        "mispredicts": int(ex.stats["mispredicts"]),
        "blocks_dispatched": int(ex.stats["blocks_dispatched"]),
        "outputs_digest": hash(tuple(tuple(v) for _, v in
                                     sorted(outs.items()))) & 0xFFFFFFFF,
    }


def main(quick: bool = False, out_json: str = "BENCH_multitenant.json"):
    requests = 4 if quick else 8
    max_new = 16 if quick else 32
    fams = {arch: _family(arch, seed) for seed, arch in enumerate(ARCHS)}
    prompts = {arch: _prompts(fams[arch][0], requests, 100 + i)
               for i, arch in enumerate(ARCHS)}

    # warm-up: compile every shape both modes will hit
    for arch, (cfg, params, channel, kw) in fams.items():
        eng = Engine(params, channel=channel, **kw)
        for p in prompts[arch]:
            eng.submit(p, max_new)
        eng.run()

    solo_rows = {}
    for arch, (cfg, params, channel, kw) in fams.items():
        eng = Engine(params, channel=channel, **kw)
        for p in prompts[arch]:
            eng.submit(p, max_new)
        t0 = time.time()
        outs = eng.run()
        solo_rows[arch] = _stream_row(arch, eng.stream, outs,
                                      time.time() - t0)

    sched = Scheduler()
    for arch, (cfg, params, channel, kw) in fams.items():
        sched.add_stream(arch, channel, params, **kw)
        for p in prompts[arch]:
            sched.submit(arch, p, max_new)
    t0 = time.time()
    outs = sched.run()
    multi_wall = time.time() - t0
    multi_rows = {arch: _stream_row(arch, sched.streams[arch], outs[arch],
                                    multi_wall) for arch in ARCHS}

    result = {
        "archs": list(ARCHS), "block_k": BLOCK_K, "n_slots": N_SLOTS,
        "requests_per_stream": requests, "max_new": max_new,
        "solo": list(solo_rows.values()),
        "multi": list(multi_rows.values()),
        "frontier": dict(sched.frontier.stats),
        # acceptance: multi-tenancy adds no host syncs and changes no token
        "bit_exact_vs_solo": all(
            multi_rows[a]["outputs_digest"] == solo_rows[a]["outputs_digest"]
            for a in ARCHS),
        "frontier_only_syncs": all(
            multi_rows[a]["syncs_per_token"] <= solo_rows[a]["syncs_per_token"]
            for a in ARCHS),
    }
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    return [*result["solo"], *[{**r, "stream": r["stream"] + "+mt"}
                               for r in result["multi"]]]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for r in main(quick=args.quick):
        print(r)
