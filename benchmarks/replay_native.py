"""Paper Table 2 reproduction: replay vs native execution delay.

TPU/JAX analogue of the paper's comparison (replay beats native because
the full stack is out of the loop):
  * native   — the full framework path: fresh process semantics modeled as
               trace+lower+compile+execute (what the GPU stack's JIT and
               runtime do at workload launch) and steady-state jit dispatch;
  * replay   — deserialize a signed recording once, then execute.
Replay wins launch-to-first-inference by the whole compile/trace cost and
matches steady-state (the executable is identical) minus Python dispatch.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_shrink
from repro.core.recorder import record
from repro.core.replay import Replayer
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.sharding import rules_for
from repro.training import steps as ST


def bench_arch(arch: str, iters: int = 30) -> dict:
    cfg = smoke_shrink(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh(model=1)
    rules = rules_for("serve", mesh.axis_names)
    batch = {"tokens": jnp.ones((1, 32), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((1, cfg.encdec.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.ones((1, cfg.vlm.num_image_tokens,
                                          cfg.d_model), jnp.bfloat16)

    fn = ST.make_prefill_step(cfg, rules, cache_len=64)

    # --- native: trace+compile happens at launch ---
    t0 = time.perf_counter()
    jitted = jax.jit(fn)
    out = jitted(params, batch)
    jax.block_until_ready(out[0]["next_tokens"])
    native_launch = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jitted(params, batch)
    jax.block_until_ready(out[0]["next_tokens"])
    native_steady = (time.perf_counter() - t0) / iters

    # --- record once ("cloud"), then replay ("TEE") ---
    rec = record(f"{arch}:prefill", fn, (params, batch), mesh=mesh)
    blob = rec.sign_with(b"k").to_bytes()
    t0 = time.perf_counter()
    # timing-only harness on bytes we just produced: unsigned load is an
    # explicit opt-in (the serving paths always verify)
    rp = Replayer(key=None, allow_unsigned=True)
    name = rp.load(blob)
    out = rp.execute(name, params, batch)
    jax.block_until_ready(out[0]["next_tokens"])
    replay_launch = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(iters):
        out = rp.execute(name, params, batch)
    jax.block_until_ready(out[0]["next_tokens"])
    replay_steady = (time.perf_counter() - t0) / iters

    return {"arch": arch,
            "native_launch_ms": round(native_launch * 1e3, 1),
            "replay_launch_ms": round(replay_launch * 1e3, 1),
            "launch_speedup": round(native_launch / replay_launch, 2),
            "native_steady_ms": round(native_steady * 1e3, 3),
            "replay_steady_ms": round(replay_steady * 1e3, 3),
            "steady_ratio": round(replay_steady / native_steady, 3)}


def main(quick: bool = False):
    archs = ["qwen2.5-3b", "xlstm-350m"] if quick else \
        ["qwen2.5-3b", "starcoder2-7b", "mixtral-8x22b", "xlstm-350m",
         "zamba2-1.2b", "whisper-large-v3"]
    return [bench_arch(a) for a in archs]


if __name__ == "__main__":
    for r in main(quick=True):
        print(r)
