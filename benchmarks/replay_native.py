"""Paper Table 2 reproduction: replay vs native execution delay, plus the
replay-side interaction-plan ablation (-> BENCH_replay.json).

Two claims, two sections:

  * native vs replay, per arch — native pays trace+lower+compile at launch
    and jit dispatch at steady state; replay deserializes a signed
    recording once and then dispatches a pinned executable (the Replayer
    fast path).  Replay wins launch by the whole compile cost and must not
    lose steady state (``replay_not_slower_than_native``, with a 5%
    tolerance: both sides run the identical executable, so steady state is
    Python-dispatch noise; CI gates on the flag).

  * the replay-plan ablation, one artifact (cody-mnist smoke prefill,
    jobs pinned) over the emulated wifi link — the compaction stack
    naive -> +dead-elim -> +poll-collapse -> +coalesce -> +fast-path must
    strictly shrink total replay delay, while the committed write sequence,
    the consumed readbacks, and the executable outputs stay bit-identical
    to the naive replay (and to live execution).  The first four rows move
    virtual link time; the fast-path row moves measured host dispatch time
    on top of the best plan, so every rung of the ladder is a real
    mechanism, not a unit change.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Workspace
from repro.configs import get_config, smoke_shrink
from repro.core.attest import fingerprint
from repro.core.netem import WIFI, NetworkEmulator
from repro.core.recorder import record
from repro.core.replay import Replayer
from repro.core.replay_passes import PlanExecutor, plan_for
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.record.cloud import REPLAY_CONSUMED_SITES
from repro.sharding import rules_for
from repro.training import steps as ST

KEY = b"replay-bench-key"
JOBS = 32            # pinned GPU job count, as in the record-time ablation
DISPATCH_CALLS = 2000    # host-dispatch sample size for the fast-path rung
STEADY_TOL = 1.05    # replay steady state within 5% of native (dispatch noise)
SHAPES = dict(cache_len=64, block_k=4, batch=1, prefill_batch=1, seq=16)

STACKS = [
    ("naive", "none"),
    ("+dead-elim", "dead"),
    ("+poll-collapse", "dead,poll"),
    ("+coalesce", "dead,poll,coalesce"),
]


def _steady_pair(fn_a, fn_b, iters: int = 30, repeats: int = 7):
    """Min-of-``repeats`` block-averaged seconds/call for two callables,
    INTERLEAVED a/b per round — the flag below gates CI, and timing the
    two sides in separate phases lets allocator/thermal drift between the
    phases masquerade as a dispatch difference."""
    best_a = best_b = float("inf")
    for _ in range(repeats):
        for fn, which in ((fn_a, "a"), (fn_b, "b")):
            t0 = time.perf_counter()
            out = None
            for _ in range(iters):
                out = fn()
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) / iters
            if which == "a":
                best_a = min(best_a, dt)
            else:
                best_b = min(best_b, dt)
    return best_a, best_b


# ------------------------------------------------------- native vs replay --
def bench_arch(arch: str, iters: int = 30) -> dict:
    cfg = smoke_shrink(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh(model=1)
    rules = rules_for("serve", mesh.axis_names)
    batch = {"tokens": jnp.ones((1, 32), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((1, cfg.encdec.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.ones((1, cfg.vlm.num_image_tokens,
                                          cfg.d_model), jnp.bfloat16)

    fn = ST.make_prefill_step(cfg, rules, cache_len=64)

    # --- native: trace+compile happens at launch ---
    t0 = time.perf_counter()
    jitted = jax.jit(fn)
    out = jitted(params, batch)
    jax.block_until_ready(out[0]["next_tokens"])
    native_launch = time.perf_counter() - t0

    # --- record once ("cloud"), then replay ("TEE") ---
    rec = record(f"{arch}:prefill", fn, (params, batch), mesh=mesh)
    blob = rec.sign_with(KEY).to_bytes()
    t0 = time.perf_counter()
    rp = Replayer(key=KEY)
    name = rp.load(blob)
    out = rp.execute(name, params, batch)
    jax.block_until_ready(out[0]["next_tokens"])
    replay_launch = time.perf_counter() - t0
    # steady state: replay runs on the pinned fast path (the launch call
    # validated); interleaved with native so drift cancels
    native_steady, replay_steady = _steady_pair(
        lambda: jitted(params, batch),
        lambda: rp.execute(name, params, batch), iters)

    return {"arch": arch,
            "native_launch_ms": round(native_launch * 1e3, 1),
            "replay_launch_ms": round(replay_launch * 1e3, 1),
            "launch_speedup": round(native_launch / replay_launch, 2),
            "native_steady_ms": round(native_steady * 1e3, 3),
            "replay_steady_ms": round(replay_steady * 1e3, 3),
            "steady_ratio": round(replay_steady / native_steady, 3),
            "fast_hits": rp.stats["fast_hits"],
            "slow_validations": rp.stats["slow_validations"],
            "replay_not_slower_than_native":
                replay_steady <= native_steady * STEADY_TOL}


# --------------------------------------------------------- plan ablation --
def _digest(tree) -> str:
    return fingerprint(*[np.asarray(x).tobytes()
                         for x in jax.tree.leaves(tree)])


def _dispatch_delay(rp: Replayer, name: str, args, calls: int,
                    repeats: int = 5) -> float:
    """Host dispatch seconds for ``calls`` executes (min of repeats)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = None
        for _ in range(calls):
            out = rp.execute(name, *args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def plan_ablation() -> dict:
    ws = Workspace(key=KEY)
    wl = ws.workload("cody-mnist", **SHAPES)
    rec = wl.compile("prefill")
    blob = rec.sign_with(KEY).to_bytes()

    params = wl.params(0)
    batch = {"tokens": jnp.ones((wl.prefill_batch, wl.seq), jnp.int32)}
    fn, _specs, _donate = wl.step("prefill")
    live_digest = _digest(jax.jit(fn)(params, batch))

    # one multi-variant replayer (signature dispatch = the pre-fast-path
    # slow path) and one sole-variant replayer (pinned fast path); both
    # run the SAME executable, so outputs must agree with live
    slow_rp = Replayer(key=KEY)
    slow_rp.load(blob, name="bench")
    rec_alt = record(rec.manifest["name"], fn,
                     (params, {"tokens": jax.ShapeDtypeStruct(
                         (wl.prefill_batch, wl.seq * 2), jnp.int32)}),
                     mesh=wl.mesh)
    slow_rp.load(rec_alt.sign_with(KEY).to_bytes(), name="bench")
    fast_rp = Replayer(key=KEY)
    fast_rp.load(blob, name="bench")
    naive_digest = _digest(fast_rp.execute("bench", params, batch))

    slow_disp = _dispatch_delay(slow_rp, "bench", (params, batch),
                                DISPATCH_CALLS)
    fast_disp = _dispatch_delay(fast_rp, "bench", (params, batch),
                                DISPATCH_CALLS)

    rows, witness, bit_exact = [], None, True
    for label, passes in STACKS:
        plan = plan_for(rec, passes, jobs=JOBS)
        ex = PlanExecutor(netem=NetworkEmulator(WIFI))
        rep = ex.run(plan)
        w = (tuple(ex.write_log()),
             tuple(ex.consumed_log(REPLAY_CONSUMED_SITES)))
        if witness is None:
            witness = w
        bit_exact &= (w == witness)
        rows.append({
            "stack": label, "net": "wifi", "passes": rep["passes"],
            "plan_virtual_s": rep["virtual_time_s"],
            "dispatch_wall_s": round(slow_disp, 6),
            "total_delay_s": round(rep["virtual_time_s"] + slow_disp, 6),
            "blocking_rts": rep["blocking_round_trips"],
            "dispatches": rep["dispatches"],
            "collapsed_spins": rep["collapsed_spins"],
            "jobs": rep["jobs"],
        })
    # the fast-path rung: best plan, but host dispatch drops the signature
    # build + dict probe for DISPATCH_CALLS steady-state executes
    best = rows[-1]
    rows.append({
        "stack": "+fast-path", "net": "wifi",
        "passes": best["passes"] + ["fastpath"],
        "plan_virtual_s": best["plan_virtual_s"],
        "dispatch_wall_s": round(fast_disp, 6),
        "total_delay_s": round(best["plan_virtual_s"] + fast_disp, 6),
        "blocking_rts": best["blocking_rts"],
        "dispatches": best["dispatches"],
        "collapsed_spins": best["collapsed_spins"],
        "jobs": best["jobs"],
    })

    delays = [r["total_delay_s"] for r in rows]
    replay_digest = _digest(fast_rp.execute("bench", params, batch))
    return {
        "rows": rows,
        "delays_s": delays,
        "monotone_virtual_time": all(a > b for a, b in zip(delays,
                                                           delays[1:])),
        "bit_exact_vs_naive_replay": bit_exact
        and replay_digest == naive_digest,
        "bit_exact_vs_live": replay_digest == live_digest,
        "all_passes_reduction_vs_naive": round(1 - delays[-1] / delays[0], 4),
        "dispatch_calls": DISPATCH_CALLS,
        "dispatch_speedup": round(slow_disp / fast_disp, 2),
        "fast_replayer_stats": dict(fast_rp.stats),
    }


def main(quick: bool = False, out_json: str = "BENCH_replay.json"):
    archs = ["qwen2.5-3b", "xlstm-350m"] if quick else \
        ["qwen2.5-3b", "starcoder2-7b", "mixtral-8x22b", "xlstm-350m",
         "zamba2-1.2b", "whisper-large-v3"]
    native_rows = [bench_arch(a) for a in archs]
    ablation = plan_ablation()
    summary = {
        "native_rows": native_rows,
        "ablation": ablation,
        "steady_tolerance": STEADY_TOL,
        "replay_not_slower_than_native":
            all(r["replay_not_slower_than_native"] for r in native_rows),
        "monotone_virtual_time": ablation["monotone_virtual_time"],
        "bit_exact_vs_naive_replay": ablation["bit_exact_vs_naive_replay"],
        "bit_exact_vs_live": ablation["bit_exact_vs_live"],
    }
    with open(out_json, "w") as f:
        json.dump(summary, f, indent=1)
    return native_rows, ablation


if __name__ == "__main__":
    rows, abl = main(quick=True)
    for r in rows:
        print(r)
    for r in abl["rows"]:
        print(r)
    print({k: v for k, v in abl.items() if k != "rows"})
