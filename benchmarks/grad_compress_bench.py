"""Gradient-compression collective bytes: fp32/bf16 psum vs int8
compressed_psum, measured by the HLO analyzer on an 8-device subprocess
(wire bytes per device; the ratio is mesh-size independent)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.training.grad_compress import compressed_psum
from repro import compat
from repro.analysis.hlo import analyze

mesh = compat.make_mesh((8,), ("data",))
x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)

def plain(v):
    return shard_map(lambda t: jax.lax.psum(t, "data"), mesh=mesh,
                     in_specs=P(None, None), out_specs=P(None, None),
                     check_rep=False)(v)

def comp(v):
    return compressed_psum(v, mesh, "data")

out = {}
with compat.set_mesh(mesh):
    for name, fn in (("psum_fp32", plain), ("psum_int8_ef", comp)):
        c = jax.jit(fn).lower(x).compile()
        a = analyze(c.as_text(), 8)
        out[name] = {"coll_bytes_per_dev": a["coll_bytes"],
                     "coll": a["coll"]}
print("JSON:" + json.dumps(out))
"""


def main(quick: bool = False):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", CODE, src],
                         capture_output=True, text=True, timeout=560)
    line = [l for l in out.stdout.splitlines() if l.startswith("JSON:")]
    if not line:
        return [{"name": "grad_compress", "us_per_call": 0,
                 "derived": "subprocess failed: " + out.stderr[-200:]}]
    d = json.loads(line[0][5:])
    fp32 = d["psum_fp32"]["coll_bytes_per_dev"]
    int8 = d["psum_int8_ef"]["coll_bytes_per_dev"]
    return [{"name": "allreduce_fp32", "us_per_call": 0,
             "derived": f"wire_bytes/dev={fp32:.0f}"},
            {"name": "allreduce_int8_ef", "us_per_call": 0,
             "derived": f"wire_bytes/dev={int8:.0f} "
                        f"(reduction {fp32/max(int8,1):.1f}x)"}]


if __name__ == "__main__":
    for r in main():
        print(r)
