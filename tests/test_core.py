"""CODY core: deferral / speculation / metasync / netem / recording — unit
+ hypothesis property tests on the system's invariants."""
import os
import pickle
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro.core import (CELLULAR, WIFI, CommitQueue, DeltaSync,
                        HistorySpeculator, MispredictError, NetworkEmulator,
                        Recording, SpeculativeRunner, TamperedRecordingError,
                        full_pack, merge, split)
from repro.core.recorder import record
from repro.core.replay import Replayer


class FakeDevice:
    """In-order device: read returns register value, write mutates."""

    def __init__(self):
        self.regs = {}
        self.exec_log = []

    def channel(self, op):
        self.exec_log.append((op.kind, op.site, op.payload))
        if op.kind == "write":
            self.regs[op.site] = op.payload
            return None
        if op.kind == "read":
            return self.regs.get(op.site, 0)
        return 3  # poll iterations


# ------------------------------------------------------------- deferral ----
@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["read", "write"]),
                          st.integers(0, 4), st.integers(0, 99)),
                min_size=1, max_size=40))
def test_deferral_preserves_program_order(ops):
    """Batched commits must execute the exact op sequence a synchronous
    driver would (the paper's correctness invariant, §4.1)."""
    sync_dev, defer_dev = FakeDevice(), FakeDevice()
    # synchronous reference
    for kind, reg, val in ops:
        if kind == "write":
            sync_dev.channel(type("O", (), {"kind": "write", "site": f"r{reg}",
                                            "payload": val})())
        else:
            sync_dev.channel(type("O", (), {"kind": "read", "site": f"r{reg}",
                                            "payload": None})())
    # deferred
    q = CommitQueue(defer_dev.channel)
    symbols = []
    for kind, reg, val in ops:
        if kind == "write":
            q.write(f"r{reg}", val)
        else:
            symbols.append((q.read(f"r{reg}"), f"r{reg}"))
    q.commit()
    assert sync_dev.exec_log == defer_dev.exec_log
    assert sync_dev.regs == defer_dev.regs
    # every symbol resolved to the synchronous value at its position
    for s, site in symbols:
        assert s.resolved


def test_symbol_reresolution_raises():
    """Satellite: a deferred read resolved twice would silently rewrite a
    value the speculation machinery already acted on — it must raise."""
    from repro.core.deferral import Symbol, SymbolReResolutionError
    s = Symbol("reg0")
    s.resolve(7)
    assert s.value == 7
    with pytest.raises(SymbolReResolutionError):
        s.resolve(8)                  # different value: definitely a bug
    with pytest.raises(SymbolReResolutionError):
        s.resolve(7)                  # same value: still a program-order bug
    assert s.value == 7               # first resolution stands


def test_externalization_commit_with_unresolved_symbol_mid_queue():
    """Satellite edge case: an externalization-forced commit with an
    UNRESOLVED symbol mid-queue — a later write's payload references an
    earlier deferred read in the same batch.  In-order client execution
    must resolve it on the fly; a symbol whose read was never enqueued
    must surface as UnresolvedSymbolError, not ship garbage."""
    from repro.core.deferral import Symbol, UnresolvedSymbolError
    dev = FakeDevice()
    dev.regs["cfg"] = 42
    q = CommitQueue(dev.channel)
    q.write("pwr", 1)
    s1 = q.read("cfg")            # unresolved while queued
    q.write("mirror", s1)         # data dependency on the mid-queue symbol
    s2 = q.read("mirror")
    q.write("probe", [s1, {"v": s1}])     # nested payload references
    assert not s1.resolved        # still symbolic before externalization
    q.flush()                     # externalization point -> one commit
    assert s1.resolved and s2.resolved
    assert s1.value == 42 and s2.value == 42
    assert dev.regs["mirror"] == 42
    assert dev.regs["probe"] == [42, {"v": 42}]
    assert q.commits == 1         # 5 interactions, one round trip
    # program order preserved through the symbolic resolution
    assert [e[:2] for e in dev.exec_log] == [
        ("write", "pwr"), ("read", "cfg"), ("write", "mirror"),
        ("read", "mirror"), ("write", "probe")]
    # a symbol from NOWHERE (its read is not in any batch) must raise
    q2 = CommitQueue(FakeDevice().channel)
    q2.write("y", Symbol("phantom"))
    with pytest.raises(UnresolvedSymbolError):
        q2.commit()


def test_barrier_forced_commit_ordering_across_batches():
    """Satellite edge case: explicit barriers split the op stream into
    coalesced batches; the device must still observe the exact global
    program order, and each barrier must cost exactly one round trip."""
    dev = FakeDevice()
    net = NetworkEmulator(WIFI)
    q = CommitQueue(dev.channel, netem=net)
    expect = []
    for batch in range(3):
        for i in range(4):
            q.write(f"b{batch}_r{i}", batch * 10 + i)
            expect.append(("write", f"b{batch}_r{i}"))
        s = q.read(f"b{batch}_r0")
        expect.append(("read", f"b{batch}_r0"))
        q.flush()                 # barrier: forces the commit HERE
        assert s.value == batch * 10      # resolved at its barrier
    assert [e[:2] for e in dev.exec_log] == expect
    assert q.commits == 3 and net.round_trips == 3
    assert q.deferred_total == 15


def test_deferral_symbolic_data_dependency():
    dev = FakeDevice()
    dev.regs["cfg"] = 7
    q = CommitQueue(dev.channel)
    s = q.read("cfg")
    q.write("cfg", s)        # write the symbol back (paper listing 1a)
    q.commit()
    assert dev.regs["cfg"] == 7
    assert q.commits == 1    # one round trip for both ops


def test_deferral_coalesces_round_trips():
    dev = FakeDevice()
    net = NetworkEmulator(WIFI)
    q = CommitQueue(dev.channel, netem=net)
    for i in range(10):
        q.write(f"r{i}", i)
    s = q.read("r5")
    assert q.need(s) == 5
    assert net.round_trips == 1   # 11 interactions, one RTT


# ----------------------------------------------------------- speculation ----
def test_speculation_hides_rtt_and_validates():
    dev = FakeDevice()
    dev.regs["status"] = 1
    net = NetworkEmulator(WIFI)
    q = CommitQueue(dev.channel, netem=net)
    spec = HistorySpeculator(k=3)
    runner = SpeculativeRunner(q, spec, lambda: dict(dev.regs),
                               lambda s, log: None)
    for _ in range(5):
        q.read("status")
        runner.commit_speculative()
        runner.sync()
    assert runner.stats["spec_commits"] >= 1
    assert runner.stats["mispredicts"] == 0
    # speculative commits did not block:
    assert net.round_trips == runner.stats["sync_commits"]


def test_speculation_mispredict_rolls_back():
    dev = FakeDevice()
    dev.regs["status"] = 1
    q = CommitQueue(dev.channel)
    spec = HistorySpeculator(k=2)
    rolled = []
    runner = SpeculativeRunner(q, spec, lambda: dict(dev.regs),
                               lambda snap, log: rolled.append(snap))
    for _ in range(3):
        q.read("status")
        runner.commit_speculative()
        runner.sync()
    dev.regs["status"] = 99          # injected wrong value (paper §7.3)
    q.read("status")
    assert runner.commit_speculative()  # speculates on stale history
    with pytest.raises(MispredictError):
        runner.sync()
    assert len(rolled) == 1
    # after rollback, speculation history knows the new value; k identical
    # observations re-enable prediction
    assert runner.stats["mispredicts"] == 1


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=4, max_size=30))
def test_speculation_never_corrupts_final_values(values):
    """Whatever the register value stream, after sync+rollback handling the
    committed log equals the true sequence (correctness despite misprediction
    — paper: 'misprediction incurs performance penalty but not correctness')."""
    dev = FakeDevice()
    seq = list(values)
    idx = [0]

    def channel(op):
        if op.kind == "read":
            v = seq[min(idx[0], len(seq) - 1)]
            idx[0] += 1
            return v
        return None

    q = CommitQueue(channel)
    spec = HistorySpeculator(k=3)
    runner = SpeculativeRunner(q, spec, lambda: idx[0], lambda s, log: None)
    got = []
    for i in range(len(seq)):
        s = q.read("r")
        runner.commit_speculative()
        try:
            runner.sync()
        except MispredictError as e:
            got.append(e.actual[0])
            continue
        got.append(s.value if not runner.outstanding else None)
    # all reads the device served, in order:
    assert idx[0] == len(seq)


# -------------------------------------------------------------- metasync ----
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_metasync_split_merge_identity(seed):
    rng = np.random.default_rng(seed)
    tree = {
        "step": np.int32(rng.integers(0, 100)),
        "pos": rng.integers(0, 50, size=8).astype(np.int32),
        "w": rng.normal(size=(64, 128)).astype(np.float32),
        "nested": {"kv": rng.normal(size=(4, 32, 16)).astype(np.float32),
                   "rng_key": rng.integers(0, 2**31, 2).astype(np.uint32)},
    }
    meta, data = split(tree)
    rebuilt = merge(tree, meta, data)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(rebuilt)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # metastate is small, program data is big
    assert any("step" in k for k in meta)
    assert any("w" in k for k in data)


def test_metastate_hints_match_tokens_not_substrings():
    """Satellite regression: hint matching must split the path into tokens
    — ``"id" in "hidden"`` / ``"count" in "encounter"`` used to classify
    large float weight leaves as metastate."""
    from repro.core.metasync import is_metastate
    big = np.zeros((64, 256), np.float32)          # > META_MAX_ELEMS
    # substring traps: 'hidden' contains 'id', 'encounter' contains 'count'
    assert not is_metastate("['hidden']", big)
    assert not is_metastate("['encounter_weights']", big)
    assert not is_metastate("['slotted_embedding']", big)   # 'slot' substring
    # true metastate tokens keep matching, incl. separators and plurals
    small = np.zeros(8, np.int32)
    for path in ("['pos']", "['committed_pos']", "['request_id']",
                 "['done']", "['slots'][0]", "['rng_key']"):
        assert is_metastate(path, small), path
    # a weight leaf named 'hidden' must land in PROGRAM DATA end to end
    tree = {"hidden": big, "pos": small}
    meta, data = split(tree)
    assert any("hidden" in k for k in data)
    assert not any("hidden" in k for k in meta)
    assert any("pos" in k for k in meta)


def test_metasync_delta_smaller_than_full():
    tree = {"pos": np.arange(1024, dtype=np.int32),
            "step": np.int32(0),
            "w": np.random.default_rng(0).normal(size=(256, 256)).astype(np.float32)}
    meta, _data = split(tree)
    ds = DeltaSync()
    first = ds.pack(meta)
    meta2 = dict(meta)
    meta2[[k for k in meta if "step" in k][0]] = np.int32(1)
    second = ds.pack(meta2)
    assert len(second) < len(first)              # delta: only changed leaves
    assert len(first) < len(full_pack(tree))    # metastate-only << full sync
    restored = DeltaSync.unpack(second, meta)
    k = [k for k in meta if "step" in k][0]
    assert int(restored[k]) == 1


# ------------------------------------------------------------- recording ----
def test_record_replay_roundtrip_and_tamper():
    key = b"signing-key"
    fn = lambda x: jnp.tanh(x) * 2.0
    rec = record("t", fn, (jax.ShapeDtypeStruct((8,), jnp.float32),))
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.codyrec")
        rec.save(p, key)
        rp = Replayer(key=key)
        rp.load(p)
        x = jnp.linspace(-1, 1, 8)
        np.testing.assert_allclose(rp.execute("t", x), fn(x), rtol=1e-6)
        # wrong key rejected
        with pytest.raises(TamperedRecordingError):
            Replayer(key=b"wrong").load(p)
        # bit flips rejected (random positions)
        blob = bytearray(open(p, "rb").read())
        for off in (10, len(blob) // 2, len(blob) - 20):
            b2 = bytearray(blob)
            b2[off] ^= 0x5A
            with pytest.raises(TamperedRecordingError):
                Replayer(key=key).load(bytes(b2))


def test_replayer_is_minimal():
    """The replayer module must not import model/config/training code —
    the paper's tiny-TCB requirement."""
    import repro.core.replay as rp
    src = open(rp.__file__).read()
    for forbidden in ("repro.models", "repro.configs", "repro.training",
                      "repro.serving"):
        assert forbidden not in src


def test_recording_embeds_cost_and_topology():
    rec = record("t", lambda x: x + 1,
                 (jax.ShapeDtypeStruct((4, 4), jnp.float32),))
    assert "topology" in rec.manifest
    assert rec.manifest["inputs"][0]["shape"] == [4, 4]
    assert "flops" in rec.manifest["cost"] or rec.manifest["cost"] == {}


def _flip_mid_byte(b: bytes) -> bytes:
    ba = bytearray(b)
    ba[len(ba) // 2] ^= 0x5A
    return bytes(ba)


def test_recording_tamper_matrix():
    """Trust boundary: a change to ANY section — manifest, payload, trees,
    signature — must surface as TamperedRecordingError on load."""
    key = b"matrix-key"
    rec = Recording({"name": "t", "static": {"cache_len": 64}},
                    b"\x01\x02" * 700,
                    pickle.dumps((None, None))).sign_with(key)
    assert Recording.from_bytes(rec.to_bytes(), key).manifest == rec.manifest
    mutations = {
        "manifest": lambda r: r.manifest.__setitem__(
            "static", {"cache_len": 9999}),
        "payload": lambda r: setattr(r, "payload", _flip_mid_byte(r.payload)),
        "trees": lambda r: setattr(r, "trees", _flip_mid_byte(r.trees)),
        "signature": lambda r: setattr(
            r, "signature",
            ("0" if r.signature[0] != "0" else "1") + r.signature[1:]),
    }
    for section, mutate in mutations.items():
        tampered = Recording(dict(rec.manifest), rec.payload, rec.trees,
                             rec.signature)
        mutate(tampered)
        with pytest.raises(TamperedRecordingError):
            Recording.from_bytes(tampered.to_bytes(), key)


# ---------------------------------------------------------------- netem ----
def test_netem_one_way_accounts_both_directions():
    net = NetworkEmulator(WIFI)
    net.one_way(1000)                        # default direction: send
    assert (net.bytes_sent, net.bytes_received) == (1000, 0)
    t1 = net.virtual_time_s
    assert t1 == pytest.approx(WIFI.rtt_s / 2 + 1000 / WIFI.bw_bytes_s)
    net.one_way_recv(500)                    # registry fetch direction
    assert (net.bytes_sent, net.bytes_received) == (1000, 500)
    assert net.virtual_time_s == pytest.approx(
        t1 + WIFI.rtt_s / 2 + 500 / WIFI.bw_bytes_s)
    with pytest.raises(ValueError):
        net.one_way(1, direction="sideways")


def test_netem_transfer_chunked_accounting():
    """transfer(): one blocking RTT + bandwidth for payload and per-chunk
    acks, billed to the right direction — registry fetch billing."""
    for direction in ("recv", "send"):
        net = NetworkEmulator(CELLULAR)
        chunks = net.transfer(200_000, chunk_size=64_000, direction=direction)
        assert chunks == 4                   # ceil(200000 / 64000)
        acks = net.ACK_BYTES * chunks
        payload_dir, ack_dir = (net.bytes_received, net.bytes_sent) \
            if direction == "recv" else (net.bytes_sent, net.bytes_received)
        assert (payload_dir, ack_dir) == (200_000, acks)
        assert net.round_trips == 1
        assert net.virtual_time_s == pytest.approx(
            CELLULAR.rtt_s + (200_000 + acks) / CELLULAR.bw_bytes_s)
    assert NetworkEmulator(WIFI).transfer(0) == 0   # nothing billed


# ------------------------------------------------- metasync round trips ----
def test_metasync_delta_roundtrip_bit_exact():
    """split -> DeltaSync -> merge reproduces the original pytree
    bit-exactly, including the no-change fast path (registry delta
    publishing leans on exactly this)."""
    rng = np.random.default_rng(7)
    tree = {"step": np.int32(3),
            "pos": rng.integers(0, 50, 8).astype(np.int32),
            "w": rng.normal(size=(64, 64)).astype(np.float32),
            "nested": {"rng_key": rng.integers(0, 2**31, 2).astype(np.uint32)}}
    meta, data = split(tree)
    ds = DeltaSync()
    wire1 = ds.pack(meta)
    restored = DeltaSync.unpack(wire1, {})     # first sync ships everything
    assert set(restored) == set(meta)
    rebuilt = merge(tree, restored, data)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(rebuilt)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))

    # no-change fast path: zero leaves shipped, base reproduced bit-exactly
    sent = ds.stats["leaves_sent"]
    wire2 = ds.pack(meta)
    assert ds.stats["leaves_sent"] == sent
    assert len(wire2) < len(wire1)
    restored2 = DeltaSync.unpack(wire2, restored)
    for k in meta:
        assert np.array_equal(np.asarray(restored2[k]), np.asarray(meta[k]))

    # single-leaf change: only that leaf crosses the wire, merge is exact
    pos_key = next(k for k in meta if "pos" in k)
    meta2 = dict(meta, **{pos_key: np.asarray(meta[pos_key]) + 1})
    wire3 = ds.pack(meta2)
    assert ds.stats["leaves_sent"] == sent + 1
    restored3 = DeltaSync.unpack(wire3, restored2)
    rebuilt3 = merge(tree, restored3, data)
    flat3 = {k: v for k, v in zip(meta, [restored3[k] for k in meta])}
    assert np.array_equal(np.asarray(flat3[pos_key]),
                          np.asarray(meta[pos_key]) + 1)
    for a, b in zip(jax.tree.leaves(merge(tree, meta2, data)),
                    jax.tree.leaves(rebuilt3)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
