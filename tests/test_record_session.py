"""Distributed recording session: device-proxy / cloud-stack split,
composable optimization passes, per-pass accounting, and the degenerate
local record path (tentpole of the record-time ablation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deferral import CommitQueue
from repro.core.netem import WIFI, NetworkEmulator
from repro.core.recorder import compile_artifact, record
from repro.core.recording import Recording
from repro.record import (CloudDryrun, DeviceProxy, FlakyRegisterDevice,
                          RecordingSession, resolve_passes)

KEY = b"session-test-key"


def _tiny():
    return (lambda x: jnp.tanh(x) * 2.0,
            (jax.ShapeDtypeStruct((8,), jnp.float32),))


@pytest.fixture(scope="module")
def artifact():
    fn, spec = _tiny()
    return compile_artifact("t", fn, spec)


def _copy(rec):
    return Recording(dict(rec.manifest), rec.payload, rec.trees)


# --------------------------------------------------- degenerate local ----
def test_local_record_is_degenerate_session():
    """core.recorder.record() == in-process session, all passes on: the
    artifact replays correctly, verifies, and every session counter in the
    manifest is zero (nothing was billed)."""
    fn, spec = _tiny()
    rec = record("t", fn, spec)
    assert rec.manifest["record_virtual_s"] == 0.0
    rs = rec.manifest["record_session"]
    assert rs["net"] == "in-process"
    assert rs["passes"] == ["deferral", "speculation", "metasync"]
    assert rs["blocking_round_trips"] == 0
    assert rs["async_round_trips"] == 0
    assert rs["bytes_sent"] == 0 and rs["bytes_received"] == 0
    assert rs["jobs"] > 0 and rs["ops_executed"] > 0   # protocol DID run
    # the recording still signs, verifies, and replays end to end
    from repro.core.replay import Replayer
    rec.sign_with(KEY)
    rp = Replayer(key=KEY)
    rp.load(rec.to_bytes())
    x = jnp.linspace(-1, 1, 8)
    np.testing.assert_allclose(rp.execute("t", x), fn(x), rtol=1e-6)


def test_session_produces_same_artifact_as_legacy(artifact):
    """Session over a real link: the Recording is byte-identical to the
    legacy local artifact (same payload/trees/exec_fingerprint — the
    session adds cost truth, never payload changes) and verifies under the
    same key; the distributed protocol cost lands in the manifest."""
    session = RecordingSession.for_profile(WIFI)
    rec = session.finalize(_copy(artifact))
    assert rec.payload == artifact.payload
    assert rec.trees == artifact.trees
    assert rec.manifest["exec_fingerprint"] == \
        artifact.manifest["exec_fingerprint"]
    assert rec.manifest["record_virtual_s"] > 0
    rs = rec.manifest["record_session"]
    assert rs["net"] == "wifi"
    assert rs["blocking_round_trips"] > 0
    rec.sign_with(KEY)
    Recording.from_bytes(rec.to_bytes(), KEY)          # verifies


# ------------------------------------------------------------ ablation ----
STACKS = [("naive", "none"), ("+deferral", "deferral"),
          ("+speculation", "deferral,speculation"), ("+metasync", "all")]


@pytest.fixture(scope="module")
def ablation(artifact):
    out = {}
    for label, passes in STACKS:
        s = RecordingSession.for_profile(WIFI, passes=passes,
                                         cloud=CloudDryrun(jobs=24))
        s.finalize(_copy(artifact))
        out[label] = s
    return out


def test_ablation_monotone_virtual_time(ablation):
    """The paper's headline (Fig. 7 / Table 1): each stacked pass strictly
    cuts virtual record time; all three together cut >= 90% vs naive."""
    times = [ablation[lbl].report()["virtual_time_s"] for lbl, _ in STACKS]
    assert all(a > b for a, b in zip(times, times[1:])), times
    assert times[-1] <= 0.1 * times[0], times


def test_ablation_pass_mechanics(ablation):
    naive = ablation["naive"].report()
    defer = ablation["+deferral"].report()
    spec = ablation["+speculation"].report()
    meta = ablation["+metasync"].report()
    # deferral coalesces round trips (paper: ~3.8-5 accesses per commit)
    assert defer["blocking_round_trips"] < naive["blocking_round_trips"] / 3
    assert naive["async_round_trips"] == 0
    # speculation converts blocking commits into async ones
    assert spec["async_round_trips"] > 0
    assert spec["blocking_round_trips"] < defer["blocking_round_trips"]
    assert spec["per_pass"]["speculation"]["spec_commits"] > 0
    assert spec["per_pass"]["speculation"].get("mispredicts", 0) == 0
    # metasync ships orders of magnitude fewer sync bytes
    naive_sync = naive["per_pass"]["wire"]["sync_bytes"]
    meta_sync = meta["per_pass"]["metasync"]["sync_bytes"]
    assert meta_sync < naive_sync / 100
    # per-pass accounting came from checkpoint/delta spans, so it never
    # exceeds the emulator's totals
    for rep in (defer, spec, meta):
        for acct in rep["per_pass"].values():
            assert acct.get("time_s", 0.0) <= rep["virtual_time_s"] + 1e-9


def test_ablation_device_invariants(ablation):
    """Whatever the pass stack, the device ends in the same hardware
    state: same registers, same number of job syncs — the optimizations
    change the wire protocol, not the program."""
    regs = [ablation[lbl].device.regs for lbl, _ in STACKS]
    assert all(r == regs[0] for r in regs[1:])
    jobs = [ablation[lbl].device.jobs_synced for lbl, _ in STACKS]
    assert jobs == [24] * 4
    # deferred sessions replay identical op logs (scoped symbol ids)
    logs = [[(o.kind, o.site, o.symbol.sid if o.symbol else None)
             for o in ablation[lbl].q.log]
            for lbl in ("+speculation", "+metasync")]
    assert logs[0] == logs[1]


def test_metasync_device_mirror_bit_exact(ablation, artifact):
    """The device's delta-synced metastate mirror equals the cloud's final
    job state metastate, leaf for leaf (§5 sync correctness)."""
    from repro.core.metasync import split
    s = ablation["+metasync"]
    meta, _ = split(s.cloud.job_state(artifact, 23))
    assert set(s.device.meta_mirror) == set(meta)
    for path, leaf in meta.items():
        np.testing.assert_array_equal(
            np.asarray(s.device.meta_mirror[path]), np.asarray(leaf))


def test_session_mispredict_rolls_back_and_recovers(artifact):
    """A register that breaks its own history mid-session forces a
    mispredict: the session bills the paper's local replay recovery,
    restores the device snapshot, REPLAYS the rolled-back log suffix so
    no executed write is lost, and still completes the record."""
    dev = FlakyRegisterDevice("job_irq_status", flip_after=10)
    s = RecordingSession.for_profile(WIFI, device=dev,
                                     cloud=CloudDryrun(jobs=24))
    rec = s.finalize(_copy(artifact))
    spec_acct = s.report()["per_pass"]["speculation"]
    assert spec_acct["mispredicts"] >= 1
    assert spec_acct["rollback_s"] > 0
    assert spec_acct["ops_replayed"] > 0               # log fast-forwarded
    assert dev.stats["rollbacks"] >= 1
    assert s.jobs == 24                                # session completed
    assert rec.payload == artifact.payload
    # rollback-via-replay converges: the device ends in the SAME register
    # state as a mispredict-free run of the same plan
    clean = RecordingSession.for_profile(WIFI, cloud=CloudDryrun(jobs=24))
    clean.finalize(_copy(artifact))
    assert dev.regs == clean.device.regs
    assert dev.jobs_synced == clean.device.jobs_synced


def test_session_is_single_use(artifact):
    """Device state, speculation history, and accounting belong to ONE
    recording — a second exercise must refuse, not mis-report."""
    s = RecordingSession.for_profile(WIFI, cloud=CloudDryrun(jobs=12))
    s.finalize(_copy(artifact))
    with pytest.raises(RuntimeError, match="single-use"):
        s.finalize(_copy(artifact))


def test_resolve_passes():
    assert resolve_passes("all") == ("deferral", "speculation", "metasync")
    assert resolve_passes(None) == ("deferral", "speculation", "metasync")
    assert resolve_passes("none") == ()
    # canonical order regardless of spelling order
    assert resolve_passes("metasync,deferral") == ("deferral", "metasync")
    assert resolve_passes(["speculation"]) == ("speculation",)
    with pytest.raises(ValueError):
        resolve_passes("deferral,warp")


# ----------------------------------------- scoped symbol ids (satellite) --
def test_symbol_ids_scoped_to_queue():
    """Regression: the module-global symbol counter leaked ids across
    sessions/tests, making op logs nondeterministic.  Two freshly built
    queues now mint identical id sequences."""
    def run_one():
        dev = DeviceProxy()
        q = CommitQueue(dev.channel)
        sids = []
        for i in range(5):
            q.write(f"r{i}", i)
            sids.append(q.read(f"r{i}").sid)
        sids.append(q.poll("p").sid)
        q.commit()
        return sids, [(o.kind, o.site, o.symbol.sid if o.symbol else None)
                      for o in q.log]
    a, b = run_one(), run_one()
    assert a == b
    assert a[0] == [0, 1, 2, 3, 4, 5]                  # fresh counter


def test_two_sessions_have_identical_op_logs(artifact):
    logs = []
    for _ in range(2):
        s = RecordingSession.for_profile(WIFI, cloud=CloudDryrun(jobs=12))
        s.finalize(_copy(artifact))
        logs.append([(o.kind, o.site, o.symbol.sid if o.symbol else None)
                     for o in s.q.log])
    assert logs[0] == logs[1]


# -------------------------------------- netem checkpoint/delta (satellite) --
def test_netem_checkpoint_delta_span_accounting():
    """checkpoint()/delta() measure a nested span without clobbering the
    globals (reset() was the only option before)."""
    net = NetworkEmulator(WIFI)
    net.round_trip(send_bytes=100, recv_bytes=50)
    outer = net.checkpoint()
    net.round_trip(send_bytes=200, recv_bytes=100)
    inner = net.checkpoint()
    net.async_trip(send_bytes=300, recv_bytes=0)
    net.one_way(1000, direction="recv")
    d_inner = net.delta(inner)
    assert d_inner["round_trips"] == 0
    assert d_inner["async_trips"] == 1
    assert d_inner["bytes_sent"] == 300
    assert d_inner["bytes_received"] == 1000
    assert d_inner["time_s"] > 0
    d_outer = net.delta(outer)
    assert d_outer["round_trips"] == 1
    assert d_outer["async_trips"] == 1
    assert d_outer["bytes_sent"] == 500
    # globals untouched by any of it
    assert net.round_trips == 2
    assert net.bytes_sent == 600
    assert net.delta(net.checkpoint()) == \
        {"time_s": 0.0, "round_trips": 0, "async_trips": 0,
         "bytes_sent": 0, "bytes_received": 0, "collapsed_spins": 0}


# ------------------------------------ registry record-on-miss via session --
def test_registry_record_on_miss_through_session(artifact):
    """RegistryService(record_profile=...) runs record-on-miss through a
    distributed session: the published meta carries record_virtual_s and
    the cold client is billed wall + virtual recorded cost."""
    from repro.registry import RecordingStore, RegistryClient, RegistryService
    store = RecordingStore(None, key=KEY)
    svc = RegistryService(store, signing_key=KEY, record_profile=WIFI)

    def record_fn(session=None):
        assert session is not None and session.netem is not None
        return session.finalize(_copy(artifact)).sign_with(KEY)

    net = NetworkEmulator(WIFI)
    cl = RegistryClient(svc, netem=net, key=KEY)
    cl.fetch("k", record_fn=record_fn)
    meta = svc.entry("k")["meta"]
    assert meta["record_virtual_s"] > 0
    assert svc.stats["record_virtual_s"] == pytest.approx(
        meta["record_virtual_s"], abs=1e-6)
    assert net.virtual_time_s >= \
        meta["record_wall_s"] + meta["record_virtual_s"]

    # legacy zero-arg record_fn keeps working (no session injected)
    calls = []
    svc2 = RegistryService(RecordingStore(None, key=KEY), signing_key=KEY)
    cl2 = RegistryClient(svc2, netem=NetworkEmulator(WIFI), key=KEY)
    cl2.fetch("k2", record_fn=lambda: calls.append(1) or
              _copy(artifact).sign_with(KEY))
    assert calls == [1]
    assert svc2.entry("k2")["meta"]["record_virtual_s"] == 0.0
