"""End-to-end system tests: the paper's full story on this machine —
record in the 'cloud' role, replay in the 'TEE' role, serve from
recordings, plus a miniature multi-device dry-run (subprocess)."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_shrink
from repro.models import model as M

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_record_then_replay_inference_end_to_end():
    """Record prefill+decode for a model, replay on NEW inputs, and check
    the replayed tokens equal direct jit execution (the paper's replay
    correctness: same stimuli -> same compute on new data)."""
    from repro.launch.record import main as record_main
    from repro.core.replay import Replayer
    from repro.training import steps as ST
    from repro.sharding import rules_for
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_shrink(get_config("qwen2.5-3b"))
    with tempfile.TemporaryDirectory() as d:
        record_main(["--arch", "qwen2.5-3b", "--out", d, "--key", "k1",
                     "--cache-len", "64", "--block-k", "4",
                     "--batch", "2", "--prefill-batch", "2", "--seq", "16"])
        rp = Replayer(key=b"k1")
        pre = rp.load(os.path.join(d, "qwen2.5-3b_prefill.codyrec"))
        dec = rp.load(os.path.join(d, "qwen2.5-3b_decode.codyrec"))

        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab_size)
        out_r, caches_r = rp.execute(pre, params, {"tokens": toks})

        mesh = make_host_mesh(model=1)
        rules = rules_for("serve", mesh.axis_names)
        prefill = jax.jit(ST.make_prefill_step(cfg, rules, cache_len=64))
        out_j, caches_j = prefill(params, {"tokens": toks})
        np.testing.assert_array_equal(np.asarray(out_r["next_tokens"]),
                                      np.asarray(out_j["next_tokens"]))

        fused = jax.jit(ST.make_fused_decode_step(cfg, rules, k=4),
                        donate_argnums=(3,))
        pos = jnp.full((2,), 16, jnp.int32)
        blk_r, _ = rp.execute(dec, params, out_r["next_tokens"], pos, caches_r)
        blk_j, _ = fused(params, out_j["next_tokens"], pos, caches_j)
        np.testing.assert_array_equal(np.asarray(blk_r["tokens"]),
                                      np.asarray(blk_j["tokens"]))
        assert rp.stats["executions"] == 2


def test_serve_from_recordings_only():
    """The engine in TEE mode: executes via the Replayer, never touching
    live jit compilation for the decode path."""
    from repro.launch.record import main as record_main
    from repro.launch.serve import build_engine

    cfg = smoke_shrink(get_config("qwen2.5-3b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        record_main(["--arch", "qwen2.5-3b", "--out", d, "--key", "k2",
                     "--cache-len", "64", "--block-k", "4",
                     "--batch", "1", "--seq", "8"])
        eng = build_engine(cfg, n_slots=1, cache_len=64, block_k=4,
                           eos_id=2, params=params, recordings_dir=d,
                           key=b"k2")
        eng.submit([5, 6, 7, 8, 9, 10, 11, 12], max_new=8)
        outs = eng.run()
        assert len(outs[0]) <= 8 and len(outs[0]) > 0


def test_train_loss_decreases():
    from repro.launch.train import main as train_main
    final = train_main(["--arch", "qwen2.5-3b", "--steps", "30",
                        "--batch", "4", "--seq", "32", "--lr", "1e-2",
                        "--log-every", "30"])
    # synthetic uniform tokens: loss should move toward ln(vocab)=5.5 from
    # the random-init value and stay finite
    assert np.isfinite(final) and final < 8.0


def test_grad_compression_trains():
    from repro.launch.train import main as train_main
    final = train_main(["--arch", "qwen2.5-3b", "--steps", "10",
                        "--batch", "2", "--seq", "16", "--grad-compress",
                        "--log-every", "10"])
    assert np.isfinite(final)


@pytest.mark.slow
def test_dryrun_mini_multidevice():
    """Miniature dry-run: 8 fake devices, 4x2 mesh, two archs — proves
    lower+compile+analyze works under SPMD in a fresh process."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from repro.configs import get_config, smoke_shrink, input_specs
from repro import compat
from repro.sharding import rules_for, shardings_for
from repro.models import model as M
from repro.training import steps as ST
from repro.analysis.hlo import analyze
mesh = compat.make_mesh((4, 2), ("data", "model"))
for arch in ("qwen2.5-3b", "zamba2-1.2b"):
    cfg = smoke_shrink(get_config(arch), vocab_size=512)
    rules = rules_for("train", mesh.axis_names)
    fn = ST.make_train_step(cfg, rules)
    state = ST.abstract_train_state(cfg)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    st_sh = shardings_for(ST.train_state_axes(cfg), state, mesh, rules)
    with compat.set_mesh(mesh):
        c = jax.jit(fn, in_shardings=(st_sh, None),
                    donate_argnums=(0,)).lower(state, batch).compile()
    cost = analyze(c.as_text(), 8)
    assert cost["flops"] > 0
    print("MINI_OK", arch, int(cost["flops"]))
"""
    out = subprocess.run([sys.executable, "-c", code, SRC],
                         capture_output=True, text=True, timeout=560)
    assert out.stdout.count("MINI_OK") == 2, out.stderr[-3000:]
