"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _close(a, b, tol):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    np.testing.assert_allclose(a, b, atol=tol, rtol=tol)


FLASH_CASES = [
    # B, Sq, Sk, H, Hkv, hd, causal, window, dtype
    (2, 128, 128, 4, 4, 64, True, 0, jnp.float32),
    (2, 128, 128, 4, 2, 64, True, 0, jnp.bfloat16),
    (1, 64, 256, 8, 2, 64, True, 0, jnp.bfloat16),    # q offset (cached)
    (2, 256, 256, 4, 1, 32, True, 64, jnp.bfloat16),  # SWA + MQA
    (1, 128, 128, 2, 2, 128, False, 0, jnp.float32),  # bidirectional
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention(case):
    B, Sq, Sk, H, Hkv, hd, causal, window, dt = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd)).astype(dt)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd)).astype(dt)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd)).astype(dt)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              blk_q=64, blk_k=64)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    _close(got, want, 0.03 if dt == jnp.float32 else 0.08)


@pytest.mark.parametrize("B,H,Hkv,hd,W,dt", [
    (2, 8, 2, 64, 256, jnp.bfloat16),
    (3, 4, 4, 128, 512, jnp.float32),
    (1, 16, 1, 64, 128, jnp.bfloat16),
])
def test_decode_attention(B, H, Hkv, hd, W, dt):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dt)
    kc = jax.random.normal(ks[1], (B, W, Hkv, hd)).astype(dt)
    vc = jax.random.normal(ks[2], (B, W, Hkv, hd)).astype(dt)
    lens = jnp.asarray(np.random.default_rng(0).integers(1, W + 1, B),
                       jnp.int32)
    got = ops.decode_attention(q, kc, vc, lens, blk_w=128)
    want = ref.decode_attention(q, kc, vc, lens)
    _close(got, want, 0.03 if dt == jnp.float32 else 0.08)


@pytest.mark.parametrize("shape,dt", [
    ((4, 37, 256), jnp.bfloat16), ((128, 512), jnp.float32),
    ((2, 3, 5, 128), jnp.bfloat16),
])
def test_rmsnorm(shape, dt):
    x = jax.random.normal(KEY, shape).astype(dt)
    s = jax.random.normal(jax.random.PRNGKey(1), shape[-1:]) * 0.1 + 1.0
    _close(ops.rmsnorm(x, s), ref.rmsnorm(x, s), 0.03)


@pytest.mark.parametrize("E,C,D,F,dt", [
    (4, 256, 128, 256, jnp.bfloat16), (2, 128, 256, 128, jnp.float32),
])
def test_moe_gmm(E, C, D, F, dt):
    x = (jax.random.normal(KEY, (E, C, D)) / np.sqrt(D)).astype(dt)
    w = jax.random.normal(jax.random.PRNGKey(2), (E, D, F)).astype(dt)
    _close(ops.moe_gmm(x, w), ref.moe_gmm(x, w),
           0.02 if dt == jnp.float32 else 0.1)


@pytest.mark.parametrize("B,nc,Q,nh,P,N", [(2, 4, 32, 3, 16, 8),
                                           (1, 8, 16, 2, 8, 16)])
def test_mamba_chunk_scan(B, nc, Q, nh, P, N):
    ks = jax.random.split(KEY, 4)
    xb = jax.random.normal(ks[0], (B, nc, Q, nh, P)) * 0.5
    Bc = jax.random.normal(ks[1], (B, nc, Q, N)) * 0.5
    Cc = jax.random.normal(ks[2], (B, nc, Q, N)) * 0.5
    cum = jnp.cumsum(-jnp.abs(jax.random.normal(ks[3], (B, nc, Q, nh))) * 0.1,
                     axis=2)
    y_k, st_k = ops.mamba_chunk_scan(xb, Bc, Cc, cum)
    h = jnp.zeros((B, nh, P, N))
    ys = []
    for c in range(nc):
        y, h = ref.mamba_chunk(xb[:, c], Bc[:, c], Cc[:, c], cum[:, c], h)
        ys.append(y)
    _close(y_k, jnp.stack(ys, 1), 0.02)
    _close(st_k, h, 0.02)


def test_mlstm_chunk_scan():
    B, nc, Q, nh, dh = 2, 4, 32, 3, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, nc, Q, nh, dh)) * 0.3
    k = jax.random.normal(ks[1], (B, nc, Q, nh, dh)) * 0.3
    v = jax.random.normal(ks[2], (B, nc, Q, nh, dh)) * 0.3
    cumf = jnp.cumsum(-jnp.abs(jax.random.normal(ks[3], (B, nc, Q, nh))) * 0.2,
                      axis=2)
    li = jnp.minimum(jax.random.normal(ks[4], (B, nc, Q, nh)), 2.0)
    y_k = ops.mlstm_chunk_scan(q, k, v, cumf, li)
    hh = jnp.zeros((B, nh, dh, dh))
    nn = jnp.zeros((B, nh, dh))
    ys = []
    for c in range(nc):
        y, hh, nn = ref.mlstm_chunk(q[:, c], k[:, c], v[:, c], cumf[:, c],
                                    li[:, c], hh, nn)
        ys.append(y)
    _close(y_k, jnp.stack(ys, 1), 0.02)


def test_kernels_match_model_math():
    """The flash kernel agrees with the model's chunked_attention path."""
    from repro.models.layers import chunked_attention
    ks = jax.random.split(KEY, 3)
    B, S, H, Hkv, hd = 2, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd)).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64)
    want = chunked_attention(q, k, v, causal=True, chunk=64)
    _close(got, want, 0.08)
