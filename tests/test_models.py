"""Per-arch smoke tests: reduced same-family configs, one forward/train step
on CPU asserting output shapes + no NaNs; prefill+decode == full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_shrink
from repro.models import model as M
from repro.training import steps as ST
from repro.training.optimizer import AdamWConfig, init_opt_state

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, with_labels=True, key=jax.random.PRNGKey(1)):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(
            key, (B, cfg.encdec.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            key, (B, cfg.vlm.num_image_tokens, cfg.d_model)).astype(jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = smoke_shrink(get_config(arch))
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = smoke_shrink(get_config(arch))
    params = M.init_params(cfg, KEY)
    state = init_opt_state(params)
    step = jax.jit(ST.make_train_step(
        cfg, None, AdamWConfig(warmup_steps=1, decay_steps=10), remat="none"))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = smoke_shrink(get_config(arch))
    if cfg.moe is not None:  # disable capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    params = M.init_params(cfg, KEY)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab_size)
    full = _batch(cfg, with_labels=False)
    full["tokens"] = toks
    pre = dict(full)
    pre["tokens"] = toks[:, :S]
    logits_full, _ = M.forward(params, cfg, full)
    ref = logits_full[:, S].astype(jnp.float32)
    _, caches = M.prefill(params, cfg, pre, cache_len=64)
    n_img = cfg.vlm.num_image_tokens if cfg.family == "vlm" else 0
    pos = jnp.full((B,), S + n_img, jnp.int32)
    got, _ = M.decode_step(params, cfg, toks[:, S], pos, caches)
    got = got.astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(ref - got))) / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.05, f"{arch}: prefill+decode diverges from forward ({rel})"


def test_param_counts_match_analytic():
    """Analytic param_count ~ actual materialized count (within 5%)."""
    for arch in ("qwen2.5-3b", "mixtral-8x22b", "xlstm-350m"):
        cfg = smoke_shrink(get_config(arch))
        params = M.init_params(cfg, KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert abs(actual - approx) / actual < 0.25, (arch, actual, approx)


def test_full_configs_are_exact():
    """Full configs match the assignment table (spot checks)."""
    q = get_config("qwen2-72b")
    assert (q.num_layers, q.d_model, q.num_heads, q.num_kv_heads,
            q.d_ff, q.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    m = get_config("mixtral-8x22b")
    assert (m.num_layers, m.moe.num_experts, m.moe.top_k) == (56, 8, 2)
    d = get_config("deepseek-v2-lite-16b")
    assert (d.mla.kv_lora_rank, d.moe.num_experts, d.moe.top_k) == (512, 64, 6)
    x = get_config("xlstm-350m")
    assert x.xlstm.slstm_at == (3, 9, 15, 21)
    z = get_config("zamba2-1.2b")
    assert z.ssm.state_dim == 64 and z.shared_every == 6


def test_swa_ring_cache_decode():
    """SWA decode with ring cache matches full-attention-with-window ref."""
    cfg = smoke_shrink(get_config("starcoder2-7b"))
    assert cfg.sliding_window == 32
    params = M.init_params(cfg, KEY)
    S = 48  # > window: ring wraps
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, S + 1), 0,
                              cfg.vocab_size)
    logits_full, _ = M.forward(params, cfg, {"tokens": toks})
    ref = logits_full[:, S].astype(jnp.float32)
    _, caches = M.prefill(params, cfg, {"tokens": toks[:, :S]}, cache_len=64)
    got, _ = M.decode_step(params, cfg, toks[:, S],
                           jnp.array([S], jnp.int32), caches)
    rel = float(jnp.max(jnp.abs(ref - got.astype(jnp.float32)))) / \
        (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.05


def test_kv_quant_decode_close():
    """int8 KV cache (per-token/head scales) stays within 10% of bf16."""
    import dataclasses as dc
    cfg = smoke_shrink(get_config("qwen2-72b"))
    cfgq = dc.replace(cfg, kv_quant=True)
    params = M.init_params(cfg, KEY)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 0,
                              cfg.vocab_size)
    ref_logits, _ = M.forward(params, cfg, {"tokens": toks})
    ref = ref_logits[:, S].astype(jnp.float32)
    _, caches = M.prefill(params, cfgq, {"tokens": toks[:, :S]}, 64)
    got, _ = M.decode_step(params, cfgq, toks[:, S],
                           jnp.full((B,), S, jnp.int32), caches)
    rel = float(jnp.max(jnp.abs(ref - got.astype(jnp.float32)))) / \
        (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.1, rel


def test_int8_weight_quant_decode_close():
    """int8 weight quantization (per-channel scales) within 15%."""
    from repro.serving.quant import quantize_params
    cfg = smoke_shrink(get_config("qwen2.5-3b"))
    params = M.init_params(cfg, KEY)
    pq = quantize_params(params)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0,
                              cfg.vocab_size)
    ref, _ = M.forward(params, cfg, {"tokens": toks})
    got, _ = M.forward(pq, cfg, {"tokens": toks})
    ref = ref.astype(jnp.float32)
    got = got.astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(ref - got))) / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.15, rel
