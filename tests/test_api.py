"""repro.api contract stability: golden registry-key derivation through
``Workload``, backward-compat of the ``launch.record``/``launch.serve``
shims (byte-identical recordings, identical serve stats), and the misuse
errors that keep unverified bytes away from ``pickle.loads``."""
import os
import pickle
import tempfile

import jax
import numpy as np
import pytest

from repro.api import Workspace, static_meta_for
from repro.configs import get_config, smoke_shrink
from repro.core.netem import WIFI
from repro.core.recording import Recording, TamperedRecordingError
from repro.record import RecordingSession
from repro.registry import key_for
from repro.registry.service import recording_to_parts

KEY = b"api-test-key"
SHAPES = dict(cache_len=64, block_k=4, batch=2, prefill_batch=1, seq=8)


# ----------------------------------------------------- key derivation ----
def test_key_for_golden_values_pinned():
    """The pure derivation must not drift across refactors: these literal
    keys were produced by the PR-5 ``key_for`` (fingerprint over the
    static-meta dict + mesh fingerprint, 16 hex chars).  If this test
    fails, published registries and replayer caches stop key-matching —
    do NOT update the golden without a migration story."""
    assert key_for("qwen2.5-3b", "decode",
                   {"kind": "decode", "cache_len": 128, "block_k": 8,
                    "batch": 4, "config_fp": "cfgfp"},
                   "meshfp") == "qwen2.5-3b/decode/c7bd577923f2d89f"
    assert key_for("qwen2.5-3b", "prefill",
                   {"kind": "prefill", "cache_len": 128, "block_k": 8,
                    "batch": 1, "seq": 16, "config_fp": "cfgfp"},
                   "meshfp") == "qwen2.5-3b/prefill/65e8b35e1789427b"


def test_workload_key_composition_contract():
    """``Workload.key`` must be exactly ``key_for(arch, kind,
    {**static_meta, config_fp}, mesh_fp)`` — the contract the record CLI
    publishes under and the serve CLI fetches by."""
    ws = Workspace(key=KEY)
    wl = ws.workload("qwen2.5-3b", **SHAPES)
    for kind in ("prefill", "decode"):
        batch = SHAPES["prefill_batch"] if kind == "prefill" \
            else SHAPES["batch"]
        static = static_meta_for(kind, cache_len=SHAPES["cache_len"],
                                 block_k=SHAPES["block_k"], batch=batch,
                                 seq=SHAPES["seq"])
        assert wl.key(kind) == key_for(
            wl.cfg.name, kind, {**static, "config_fp": wl.cfg.fingerprint()},
            wl.mesh_fp)
    # smoke suffix is identity-irrelevant; derivation is deterministic
    assert wl.cfg.name.endswith("-smoke")
    assert wl.key("decode").startswith("qwen2.5-3b/decode/")
    wl2 = Workspace(key=KEY).workload("qwen2.5-3b", **SHAPES)
    assert wl2.key("prefill") == wl.key("prefill")
    assert wl2.key("decode") == wl.key("decode")
    # decode identity excludes seq: a decode recording serves any prompt
    wl3 = Workspace(key=KEY).workload("qwen2.5-3b",
                                      **dict(SHAPES, seq=32))
    assert wl3.key("decode") == wl.key("decode")
    assert wl3.key("prefill") != wl.key("prefill")


# ------------------------------------------------------- shim compat ----
def test_api_record_bit_exact_vs_legacy_session():
    """``Workload.record(artifact=...)`` must produce byte-identical
    recordings to the legacy path (hand-built RecordingSession over the
    same compiled artifact) — manifest, payload, trees, and signature."""
    ws = Workspace(key=KEY, net="wifi")
    wl = ws.workload("cody-mnist", **SHAPES)
    base = wl.compile("prefill")
    api_rec = wl.record("prefill", artifact=base)
    legacy = RecordingSession.for_profile(WIFI).finalize(
        Recording(dict(base.manifest), base.payload, base.trees))
    assert api_rec.payload == legacy.payload == base.payload
    assert api_rec.trees == legacy.trees
    assert api_rec.manifest == legacy.manifest
    api_signed = Recording(dict(api_rec.manifest), api_rec.payload,
                           api_rec.trees).sign_with(KEY)
    legacy_signed = Recording(dict(legacy.manifest), legacy.payload,
                              legacy.trees).sign_with(KEY)
    assert api_signed.to_bytes() == legacy_signed.to_bytes()
    # and the session accounting went into both manifests identically
    assert api_rec.manifest["record_virtual_s"] > 0
    assert ws.report()["sessions"][0]["virtual_time_s"] == \
        api_rec.manifest["record_virtual_s"]


def test_record_cli_shim_publishes_the_api_keys():
    """The record CLI (now a shim) must keep writing the flat file AND
    publishing under the canonical API key: an API workspace with the
    same shapes fetches the exact bytes the CLI saved."""
    from repro.launch.record import main as record_main
    with tempfile.TemporaryDirectory() as d:
        record_main(["--arch", "cody-mnist", "--kinds", "prefill",
                     "--out", d, "--key", KEY.decode(), "--cache-len", "64",
                     "--block-k", "4", "--batch", "2", "--seq", "8",
                     "--net", "wifi"])
        with open(os.path.join(d, "cody-mnist_prefill.codyrec"), "rb") as f:
            flat = f.read()
        Recording.from_bytes(flat, KEY)              # flat file verifies
        ws = Workspace(registry=os.path.join(d, "registry"), key=KEY,
                       net="wifi")
        wl = ws.workload("cody-mnist", **SHAPES)
        assert wl.fetch("prefill") == flat           # same key, same bytes


def test_serve_shim_identical_stats_vs_api():
    """``build_engine`` (now a shim) must behave exactly like driving the
    API directly: same tokens, same engine stats, stream for stream."""
    from repro.launch.serve import REC_SEQ, build_engine
    cfg = smoke_shrink(get_config("cody-mnist"))
    params_key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(3, cfg.vocab_size, 6)) for _ in range(4)]

    from repro.models import model as M
    params = M.init_params(cfg, params_key)
    shim_eng = build_engine(cfg, n_slots=2, cache_len=64, block_k=4,
                            eos_id=2, params=params)
    wl = Workspace().workload(cfg, cache_len=64, block_k=4, batch=2,
                              prefill_batch=1, seq=REC_SEQ)
    api_eng = wl.engine(params=params)
    outs = {}
    for label, eng in (("shim", shim_eng), ("api", api_eng)):
        for p in prompts:
            eng.submit(p, max_new=8)
        outs[label] = eng.run()
    assert outs["shim"] == outs["api"]
    assert dict(shim_eng.stats) == dict(api_eng.stats)


# ------------------------------------------------------ misuse errors ----
SIDE_EFFECTS = []


class _Evil:
    def __reduce__(self):
        return (SIDE_EFFECTS.append, ("pwned",))


def test_workspace_registry_requires_key():
    """A keyless registry workspace could never verify fetched bytes —
    refuse at construction, long before any fetch."""
    with pytest.raises(ValueError, match="signing key"):
        Workspace(registry=":memory:", key=b"")
    with pytest.raises(ValueError, match="signing key"):
        Workspace(registry="/tmp/somewhere")


def test_fetch_without_registry_is_an_error():
    ws = Workspace(key=KEY)
    wl = ws.workload("cody-mnist", **SHAPES)
    with pytest.raises(RuntimeError, match="no registry"):
        wl.fetch("prefill")


def test_unsigned_fetch_rejected_before_any_unpickle():
    """A recording signed under the WRONG key, smuggled into the store
    with a malicious pickle in its trees, must be rejected by the HMAC
    check before ``pickle.loads`` can run."""
    SIDE_EFFECTS.clear()
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    wl = ws.workload("cody-mnist", **SHAPES)
    evil = Recording({"name": "evil", "static": {}}, b"payload",
                     pickle.dumps(_Evil())).sign_with(b"attacker-key")
    # the service refuses to publish a foreign-signed recording at all...
    with pytest.raises(TamperedRecordingError):
        wl.publish(evil, key=wl.key("prefill"))
    # ...so smuggle it straight into the store, bypassing the service
    ws.store.put(wl.key("prefill"),
                 recording_to_parts(evil, ws.store.chunk_size), meta={})
    with pytest.raises(TamperedRecordingError):
        wl.fetch("prefill")
    assert SIDE_EFFECTS == []                 # the pickle never executed
