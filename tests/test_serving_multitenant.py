"""Layered serving stack: two model families through one Scheduler —
per-stream bit-exactness vs solo serving, slot pressure, preemption with
bit-exact resume, stalled-stream eviction, and the ExecutionChannel trust
boundary / netem-billed transport."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_shrink
from repro.core.channel import (ChannelCapabilityError, LiveChannel,
                                NetemBilledChannel)
from repro.core.netem import WIFI, NetworkEmulator
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import stream_kwargs
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler
from repro.sharding import rules_for
from repro.training import steps as ST

BLOCK_K = 4
CACHE_LEN = 96
N_SLOTS = 2


def _family(arch, seed, decode_wrap=None):
    """(cfg, params, channel, stream kwargs) for one model family.  The
    channel is built once per call so solo and multi-tenant runs of the
    same family share jitted executables (and compile cost)."""
    cfg = smoke_shrink(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    rules = rules_for("serve", make_host_mesh(model=1).axis_names)
    prefill = jax.jit(ST.make_prefill_step(cfg, rules, CACHE_LEN))
    batched = None
    if cfg.family in ("dense", "moe") and not cfg.sliding_window:
        batched = jax.jit(ST.make_batched_prefill_step(cfg, rules, CACHE_LEN))
    decode = jax.jit(
        ST.make_fused_decode_step(cfg, rules, k=BLOCK_K, eos_id=2),
        donate_argnums=(3,))
    if decode_wrap is not None:
        decode = decode_wrap(decode)
    channel = LiveChannel(prefill, decode, batched)
    kw = stream_kwargs(cfg, n_slots=N_SLOTS, cache_len=CACHE_LEN,
                       block_k=BLOCK_K, eos_id=2, pipeline_depth=4)
    return cfg, params, channel, kw


def _prompts(cfg, n, seed, plen_range=(4, 12)):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(3, cfg.vocab_size,
                              int(rng.integers(*plen_range))))
            for _ in range(n)]


def test_two_families_concurrent_bit_exact():
    """ISSUE-3 acceptance: an attention family (speculating) and a
    recurrent ssm family (speculation gated off) served CONCURRENTLY
    through one Scheduler produce exactly the tokens each produces when
    served alone."""
    dense = _family("qwen2.5-3b", seed=0)
    ssm = _family("xlstm-350m", seed=1)
    assert ssm[3]["speculate"] is False        # family gate, not caller's

    workloads = {"dense": (dense, _prompts(dense[0], 4, 21)),
                 "ssm": (ssm, _prompts(ssm[0], 4, 22))}
    solo = {}
    for name, ((cfg, params, channel, kw), prompts) in workloads.items():
        eng = Engine(params, channel=channel, **kw)
        for p in prompts:
            eng.submit(p, 14)
        solo[name] = eng.run()

    sched = Scheduler()
    for name, ((cfg, params, channel, kw), prompts) in workloads.items():
        sched.add_stream(name, channel, params, **kw)
        for p in prompts:
            sched.submit(name, p, 14)
    multi = sched.run()

    assert multi["dense"] == solo["dense"]
    assert multi["ssm"] == solo["ssm"]
    # the dense stream really speculated; the recurrent one never did
    assert sched.streams["dense"].stats["spec_blocks"] > 0
    assert sched.streams["ssm"].stats["spec_blocks"] == 0
    # shared speculator, isolated histories: every key carries its stream
    assert all(k.split("::")[0] in ("dense", "ssm")
               for k in sched.spec.history)
    for ex in sched.streams.values():
        for req in ex.requests.values():
            assert req.done and req.committed == len(req.generated)


def test_multitenant_syncs_match_solo():
    """The frontier stays the ONLY host<->device sync under multi-tenancy:
    each stream's host-sync count equals its solo-serving count (no extra
    cross-stream stalls)."""
    dense = _family("qwen2.5-3b", seed=0)
    prompts = _prompts(dense[0], 4, 31)

    cfg, params, channel, kw = dense
    eng = Engine(params, channel=channel, **kw)
    for p in prompts:
        eng.submit(p, 12)
    solo_out = eng.run()
    solo_syncs = eng.stats["host_syncs"]

    sched = Scheduler()
    sched.add_stream("a", channel, params, **kw)
    ssm = _family("xlstm-350m", seed=1)
    sched.add_stream("b", ssm[2], ssm[1], **ssm[3])
    for p in prompts:
        sched.submit("a", p, 12)
    for p in _prompts(ssm[0], 3, 32):
        sched.submit("b", p, 12)
    multi = sched.run()
    assert multi["a"] == solo_out
    assert sched.streams["a"].stats["host_syncs"] == solo_syncs
    # every readback in the run is accounted at the frontier
    total = sum(ex.stats["host_syncs"] for ex in sched.streams.values())
    assert sched.frontier.stats["host_syncs"] == total


def test_slot_pressure_defers_admission():
    """A global ``max_live_slots`` budget applies back-pressure across
    tenants without changing any stream's tokens."""
    a = _family("qwen2.5-3b", seed=0)
    prompts_a = _prompts(a[0], 3, 41)
    prompts_b = _prompts(a[0], 3, 42)

    solo = {}
    for key, prompts in (("a", prompts_a), ("b", prompts_b)):
        eng = Engine(a[1], channel=a[2], **a[3])
        for p in prompts:
            eng.submit(p, 10)
        solo[key] = eng.run()

    sched = Scheduler(max_live_slots=2)
    sched.add_stream("a", a[2], a[1], **a[3])
    sched.add_stream("b", a[2], a[1], **a[3])
    for p in prompts_a:
        sched.submit("a", p, 10)
    for p in prompts_b:
        sched.submit("b", p, 10)
    outs = sched.run()
    assert outs["a"] == solo["a"] and outs["b"] == solo["b"]
    assert sched.live_slots() == 0
    deferred = sum(ex.stats["admissions_deferred"]
                   for ex in sched.streams.values())
    assert deferred > 0


def test_preempt_resume_bit_exact():
    """Eviction mid-serve, then resume: committed tails survive, evicted
    requests re-prefill ``prompt + generated[:-1]`` and finish with
    exactly the tokens of an uninterrupted run (deterministic decode)."""
    cfg, params, channel, kw = _family("qwen2.5-3b", seed=0)
    prompts = _prompts(cfg, 3, 51)

    eng = Engine(params, channel=channel, **kw)
    for p in prompts:
        eng.submit(p, 16)
    reference = eng.run()

    sched = Scheduler()
    sched.add_stream("s", channel, params, **kw)
    for p in prompts:
        sched.submit("s", p, 16)
    for _ in range(3):                 # partial progress, blocks in flight
        sched.step()
    evicted = sched.preempt("s")
    assert evicted                      # something was actually running
    assert sched.streams["s"].slots.done.all()
    assert sched.stats()["preemptions"] == 1
    outs = sched.run()
    assert outs["s"] == reference


def _frozen_pos_wrap(base):
    """A 'hung device': blocks return but positions never advance and no
    sequence ever finishes — the stall the scheduler must evict."""
    def fn(params, toks, pos, caches):
        out, caches = base(params, toks, pos, caches)
        return {"tokens": out["tokens"], "pos": pos,
                "done": jnp.zeros_like(out["done"])}, caches
    return fn


def test_stalled_stream_evicted_healthy_stream_unaffected():
    healthy = _family("qwen2.5-3b", seed=0)
    frozen = _family("qwen2.5-3b", seed=1,
                     decode_wrap=lambda d: _frozen_pos_wrap(d))
    prompts_h = _prompts(healthy[0], 2, 61)

    eng = Engine(healthy[1], channel=healthy[2], **healthy[3])
    for p in prompts_h:
        eng.submit(p, 8)
    solo = eng.run()

    sched = Scheduler(stall_limit=2)
    sched.add_stream("healthy", healthy[2], healthy[1], **healthy[3])
    sched.add_stream("frozen", frozen[2], frozen[1], **frozen[3])
    for p in prompts_h:
        sched.submit("healthy", p, 8)
    for p in _prompts(frozen[0], 2, 62):
        sched.submit("frozen", p, 200)
    outs = sched.run(max_blocks=40)
    stats = sched.stats()
    assert stats["preemptions"] >= 1
    assert sched.streams["frozen"].stats["evicted_requests"] >= 1
    # the public stats() surfaces the stall state the eviction ran on:
    # the frozen stream's stall streak reached the limit at least once
    assert stats["streams"]["frozen"]["stall_hwm"] >= 2
    assert stats["streams"]["frozen"]["evicted_requests"] >= 1
    assert stats["streams"]["healthy"]["stall_hwm"] == 0
    assert outs["healthy"] == solo                  # isolation held
    assert all(r.done for r in sched.streams["healthy"].requests.values())
    # the frozen stream never legitimately finished a request
    assert not any(r.done and not r.failed
                   for r in sched.streams["frozen"].requests.values())


def test_replay_channel_preemption_unsupported():
    """A fixed-prompt-shape channel cannot resume evicted prefixes; the
    stream must refuse eviction loudly rather than corrupt requests."""
    from repro.serving.executor import PreemptionUnsupportedError
    cfg, params, channel, kw = _family("qwen2.5-3b", seed=0)
    pinned = LiveChannel(channel._prefill, channel._decode,
                         fixed_prompt_len=8)
    sched = Scheduler()
    sched.add_stream("s", pinned, params, **kw)
    sched.submit("s", list(range(3, 11)), 8)
    sched.step()
    with pytest.raises(PreemptionUnsupportedError):
        sched.preempt("s")


def test_stalled_pinned_channel_does_not_crash_scheduler():
    """Regression: auto-eviction of a stalled stream whose channel pins
    the prefill shape (replay mode) must NOT propagate
    PreemptionUnsupportedError and abort the other tenants — the stream
    is left in place and marked unevictable."""
    healthy = _family("qwen2.5-3b", seed=0)
    frozen = _family("qwen2.5-3b", seed=1,
                     decode_wrap=lambda d: _frozen_pos_wrap(d))
    pinned = LiveChannel(frozen[2]._prefill, frozen[2]._decode,
                         fixed_prompt_len=8)
    prompts_h = _prompts(healthy[0], 2, 81)

    eng = Engine(healthy[1], channel=healthy[2], **healthy[3])
    for p in prompts_h:
        eng.submit(p, 8)
    solo = eng.run()

    sched = Scheduler(stall_limit=2)
    sched.add_stream("healthy", healthy[2], healthy[1], **healthy[3])
    sched.add_stream("pinned", pinned, frozen[1], **frozen[3])
    for p in prompts_h:
        sched.submit("healthy", p, 8)
    for _ in range(2):
        sched.submit("pinned", list(range(3, 11)), 200)
    outs = sched.run(max_blocks=40)         # must not raise
    stats = sched.stats()
    assert stats["eviction_unsupported"] == 1
    assert stats["preemptions"] == 0
    assert stats["streams"]["pinned"]["unevictable"] is True
    assert outs["healthy"] == solo


def test_netem_billed_channel_logs_and_bills():
    """The record/emulation transport: every dispatch is billed to the
    emulated link (async — dispatches never stall) and logged as the
    interaction trace, with identical served tokens."""
    cfg, params, channel, kw = _family("qwen2.5-3b", seed=0)
    prompts = _prompts(cfg, 3, 71)

    eng = Engine(params, channel=channel, **kw)
    for p in prompts:
        eng.submit(p, 10)
    reference = eng.run()

    net = NetworkEmulator(WIFI)
    billed = NetemBilledChannel(channel, net)
    eng2 = Engine(params, channel=billed, **kw)
    for p in prompts:
        eng2.submit(p, 10)
    outs = eng2.run()
    assert outs == reference
    dispatches = eng2.stats["blocks_dispatched"] + \
        eng2.stats["prefill_dispatches"]
    assert len(billed.log) == dispatches
    assert net.async_trips == dispatches and net.round_trips == 0
    assert net.bytes_sent == dispatches * NetemBilledChannel.DISPATCH_BYTES
    steps = {row[0] for row in billed.log}
    assert "decode_block" in steps and steps <= {
        "prefill", "batched_prefill", "decode_block"}


def test_channel_capability_errors():
    ch = LiveChannel(lambda p, b: None, lambda p, t, po, c: None)
    assert not ch.supports_batched_prefill
    with pytest.raises(ChannelCapabilityError):
        ch.batched_prefill(None, None, None)


def test_channel_module_is_model_free():
    """Trust boundary: the channel module (the replay channel's home) must
    not import model/config/training/serving code — a replay-channel
    stream reaches decode with only verified executables in the TCB."""
    import repro.core.channel as ch
    src = open(ch.__file__).read()
    for forbidden in ("repro.models", "repro.configs", "repro.training",
                      "repro.serving"):
        assert forbidden not in src
