"""Replay-time plan compaction + Replayer fast path.

Covers the replay-side pass stack (dead-register-access elimination,
poll-spin collapsing, commit coalescing): per-pass bit-exactness of the
committed write sequence and consumed readbacks vs the naive replay on
BOTH recorded kinds (prefill + decode), poll-collapse netem billing
exactness, tamper rejection of compacted plans, the coalesce dispatch
arithmetic — and the Replayer's precompiled-dispatch fast path (pinned
counters, multi-variant invalidation, deterministic ``manifest()``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Workspace
from repro.core.attest import TamperedRecordingError, fingerprint
from repro.core.netem import WIFI, NetworkEmulator
from repro.core.recorder import record
from repro.core.replay import ReplayArgumentError, Replayer
from repro.core.replay_passes import (FUSE_JOBS, PlanExecutor, plan_for,
                                      replay_plan_report,
                                      resolve_replay_passes, verified_plan)
from repro.record.cloud import REPLAY_CONSUMED_SITES, CloudDryrun
from repro.record.device import POLL_TRIPS

KEY = b"replay-pass-test-key"
JOBS = 8
SHAPES = dict(cache_len=32, block_k=4, batch=2, prefill_batch=1, seq=8)

STACKS = ["none", "dead", "dead,poll", "all"]


@pytest.fixture(scope="module")
def ws():
    return Workspace(key=KEY, net="wifi")


@pytest.fixture(scope="module")
def wl(ws):
    return ws.workload("cody-mnist", **SHAPES)


@pytest.fixture(scope="module", params=["prefill", "decode"])
def rec(request, wl):
    """One compiled artifact per recorded kind — the two model kinds the
    per-pass bit-exactness sweep runs over."""
    r = wl.compile(request.param)
    r.sign_with(KEY)
    return r


def _run(rec_, passes, jobs=JOBS):
    plan = plan_for(rec_, passes, jobs=jobs)
    ex = PlanExecutor(netem=NetworkEmulator(WIFI))
    rep = ex.run(plan)
    return plan, ex, rep


# ------------------------------------------------------------ pass stack --
def test_resolve_replay_passes_spellings():
    assert resolve_replay_passes("all") == ("dead", "poll", "coalesce")
    assert resolve_replay_passes(None) == ("dead", "poll", "coalesce")
    assert resolve_replay_passes("none") == ()
    assert resolve_replay_passes("naive") == ()
    # canonical order is imposed regardless of spelling order
    assert resolve_replay_passes("coalesce,dead") == ("dead", "coalesce")
    with pytest.raises(ValueError, match="unknown replay passes"):
        resolve_replay_passes("dead,bogus")


def test_per_pass_bit_exact_vs_naive_and_monotone(rec):
    """Every pass stack must shrink virtual replay time WITHOUT changing
    the committed write sequence or the consumed completion readbacks —
    checked per recorded kind (prefill and decode)."""
    witness, prev_t = None, None
    for passes in STACKS:
        _plan, ex, rep = _run(rec, passes)
        w = (tuple(ex.write_log()),
             tuple(ex.consumed_log(REPLAY_CONSUMED_SITES)))
        if witness is None:
            witness = w
        assert w == witness, f"compaction changed replayed state at {passes}"
        if prev_t is not None:
            assert rep["virtual_time_s"] < prev_t, \
                f"stack {passes} did not strictly reduce virtual time"
        prev_t = rep["virtual_time_s"]
    # the consumed chain carries real values: flush polls resolve the trip
    # count, flush ids advance monotonically job over job
    sites = [s for s, _v in witness[1]]
    assert sites.count("latest_flush_id") == JOBS
    flush_ids = [v for s, v in witness[1] if s == "latest_flush_id"]
    assert flush_ids == list(range(1, JOBS + 1))


def test_dead_elim_keeps_exactly_the_consumed_chain(rec):
    plan, _ex, _rep = _run(rec, "dead")
    read_sites = set(plan.op_sites("read"))
    assert read_sites == {"latest_flush_id", "job_status"}
    assert plan.acct["dead"]["reads_dropped"] > 0
    # writes are never dropped: they are what drives the hardware
    naive = plan_for(rec, "none", jobs=JOBS)
    assert plan.op_sites("write") == naive.op_sites("write")


def test_poll_collapse_billing_exact(rec):
    """Collapsing a POLL_TRIPS spin into one wait must remove exactly
    jobs*(POLL_TRIPS-1) blocking round trips, bill the collapsed trips to
    the emulator's counter, and shave the exact per-trip virtual time."""
    _p1, _e1, before = _run(rec, "dead")
    _p2, _e2, after = _run(rec, "dead,poll")
    spared = JOBS * (POLL_TRIPS - 1)
    assert before["blocking_round_trips"] - after["blocking_round_trips"] \
        == spared
    assert after["collapsed_spins"] == spared
    assert before["collapsed_spins"] == 0
    # each spared trip cost one RTT + the batch's wire bytes (256 send
    # floor + 64 header + 8 for the one readback)
    per_trip = WIFI.rtt_s + (256 + 72) / WIFI.bw_bytes_s
    assert before["virtual_time_s"] - after["virtual_time_s"] \
        == pytest.approx(spared * per_trip)


def test_coalesce_dispatch_arithmetic(rec):
    _plan, _ex, rep = _run(rec, "all")
    assert rep["dispatches"] == -(-JOBS // FUSE_JOBS)
    # without dead-elim the init probes survive as ONE fused leading
    # dispatch (non-job segments never fuse into job batches)
    plan2, _ex2, rep2 = _run(rec, "poll,coalesce")
    assert rep2["dispatches"] == 1 + -(-JOBS // FUSE_JOBS)
    assert plan2.groups[0].label == "init"


def test_consumed_sites_exposed_by_cloud(rec):
    cloud = CloudDryrun(jobs=JOBS)
    assert cloud.consumed_readbacks() == REPLAY_CONSUMED_SITES
    # every consumed site must actually appear in the per-job plan
    sites = {op[1] for _seg, ops in cloud.interaction_plan(rec)
             for op in ops}
    assert REPLAY_CONSUMED_SITES <= sites


def test_verified_plan_rejects_tampered_blob(rec):
    """A compacted plan is only built from a recording that verifies under
    the caller's key — flip one byte anywhere and the plan never exists."""
    blob = rec.to_bytes()
    plan, r = verified_plan(blob, KEY, "all", jobs=JOBS)
    assert plan.source_fingerprint == fingerprint(r.payload) \
        == r.manifest["exec_fingerprint"]
    bad = bytearray(blob)
    bad[len(bad) // 2] ^= 0xFF
    with pytest.raises(TamperedRecordingError):
        verified_plan(bytes(bad), KEY, "all", jobs=JOBS)
    with pytest.raises(TamperedRecordingError):
        verified_plan(blob, b"wrong-key", "all", jobs=JOBS)


def test_plan_executor_single_use(rec):
    plan = plan_for(rec, "all", jobs=JOBS)
    ex = PlanExecutor(netem=NetworkEmulator(WIFI))
    ex.run(plan)
    with pytest.raises(RuntimeError, match="single-use"):
        ex.run(plan)


def test_replay_plan_report_convenience(rec):
    rep = replay_plan_report(rec, "all", netem=NetworkEmulator(WIFI),
                             jobs=JOBS)
    assert rep["passes"] == ["dead", "poll", "coalesce"]
    assert rep["virtual_time_s"] > 0
    assert rep["per_pass"]["coalesce"]["dispatches_after"] \
        == rep["dispatches"]


# -------------------------------------------------- Replayer fast path --
def _record_double(n=4, name="double"):
    r = record(name, lambda x: x * 2.0,
               (jax.ShapeDtypeStruct((n,), jnp.float32),))
    r.sign_with(KEY)
    return r


def test_fast_path_counters_pinned():
    """First execute validates (slow path, pins the executable); every
    later same-name execute is a fast hit."""
    rp = Replayer(key=KEY)
    rp.load(_record_double().to_bytes(), name="double")
    x = jnp.ones(4, jnp.float32)
    for _ in range(5):
        out = rp.execute("double", x)
    np.testing.assert_allclose(np.asarray(out), 2.0)
    assert rp.stats["slow_validations"] == 1
    assert rp.stats["fast_hits"] == 4
    assert rp.stats["executions"] == 5


def test_fast_path_disabled_by_second_variant():
    """Loading a second aval variant under a pinned name must drop the pin:
    multi-variant names always dispatch by signature (and still raise the
    clear argument error on a miss)."""
    rp = Replayer(key=KEY)
    rp.load(_record_double(4).to_bytes(), name="double")
    x4 = jnp.ones(4, jnp.float32)
    rp.execute("double", x4)            # pins
    rp.execute("double", x4)            # fast hit
    assert rp.stats["fast_hits"] == 1
    rp.load(_record_double(8).to_bytes(), name="double")
    rp.execute("double", jnp.ones(8, jnp.float32))
    rp.execute("double", x4)
    assert rp.stats["fast_hits"] == 1   # no hits after invalidation
    assert rp.stats["slow_validations"] == 3
    with pytest.raises(ReplayArgumentError):
        rp.execute("double", jnp.ones(5, jnp.float32))


def test_manifest_deterministic():
    """Satellite regression: ``manifest(name)`` must never silently pick an
    arbitrary variant — sole variant returns, multi-variant raises unless
    a signature selects, ``manifests()`` lists all."""
    rp = Replayer(key=KEY)
    rp.load(_record_double(4).to_bytes(), name="double")
    assert rp.manifest("double")["inputs"][0]["shape"] == [4]
    rp.load(_record_double(8).to_bytes(), name="double")
    with pytest.raises(ReplayArgumentError, match="2 loaded variants"):
        rp.manifest("double")
    sig8 = (((8,), "float32"),)
    assert rp.manifest("double", signature=sig8)["inputs"][0]["shape"] == [8]
    with pytest.raises(ReplayArgumentError, match="no variant"):
        rp.manifest("double", signature=(((5,), "float32"),))
    assert [m["inputs"][0]["shape"] for m in rp.manifests("double")] \
        == [[4], [8]]


def test_workspace_report_surfaces_replayer_stats(wl, ws):
    """The serving stack reads fast-path hit counts through the workload
    and workspace reports; ``Workload.replay`` reports land there too."""
    rp = Replayer(key=KEY)
    rp.load(_record_double(4, name="stats").to_bytes(), name="stats")
    x = jnp.ones(4, jnp.float32)
    for _ in range(3):
        rp.execute("stats", x)
    wl.replayers.append(rp)
    stats = wl.replayer_stats()
    assert stats["fast_hits"] == 2 and stats["slow_validations"] == 1
    rep = ws.report()
    assert rep["replayer_stats"]["fast_hits"] >= 2
    assert "replays" in rep


def test_workload_replay_reports(ws, wl, rec):
    """``Workload.replay`` prices the compacted plan over the workspace
    link and appends to the report stream, mirroring ``record``."""
    n_before = len(wl.replays)
    full = wl.replay(artifact=rec, passes="all", jobs=JOBS)
    naive = wl.replay(artifact=rec, passes="none", jobs=JOBS)
    assert len(wl.replays) == n_before + 2
    assert full["virtual_time_s"] < naive["virtual_time_s"]
    kinds = {k for k, _r in wl.replays}
    assert kinds <= {"prefill", "decode"}
    assert len(ws.report()["replays"]) >= 2
