"""Recording registry: content-addressed store, single-flight
record-on-miss service, netem-billed resumable client, trust boundary."""
import os
import pickle
import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attest import UnverifiedRecordingError
from repro.core.netem import WIFI, NetworkEmulator
from repro.core.recorder import record
from repro.core.recording import Recording, TamperedRecordingError
from repro.core.replay import Replayer
from repro.registry import (FetchInterrupted, LRUBytes, RecordingStore,
                            RegistryClient, RegistryIntegrityError,
                            RegistryMissError, RegistryService, key_arch,
                            key_for)

KEY = b"registry-test-key"


@pytest.fixture(scope="module")
def real_recording():
    """One real (compiled) recording shared by the module's tests."""
    def fn(x):
        return jnp.tanh(x) * 2.0

    rec = record("unit/tanh/abc", fn,
                 (jax.ShapeDtypeStruct((16,), jnp.float32),))
    rec.sign_with(KEY)
    return rec


def synthetic_recording(payload_bytes: int = 200_000, seed: int = 0,
                        static=None) -> Recording:
    """Signed recording with an incompressible payload (no compile cost);
    enough chunks at chunk_size=32k to exercise chunked/resumable paths."""
    rng = np.random.default_rng(seed)
    manifest = {"name": "synthetic", "static": static or {},
                "record_wall_s": 2.0}
    return Recording(manifest, rng.bytes(payload_bytes),
                     pickle.dumps((None, None))).sign_with(KEY)


def make_registry(root=None, chunk_size=32 * 1024):
    store = RecordingStore(root, key=KEY, chunk_size=chunk_size)
    return store, RegistryService(store, signing_key=KEY)


# ------------------------------------------------------------- key_for ----
def test_key_for_is_deterministic_and_shape_sensitive():
    shapes = {"kind": "decode", "batch": 4, "cache_len": 128}
    k1 = key_for("qwen2.5-3b", "decode", shapes, "meshfp")
    assert k1 == key_for("qwen2.5-3b", "decode", dict(shapes), "meshfp")
    assert k1.startswith("qwen2.5-3b/decode/")
    assert k1 != key_for("qwen2.5-3b", "decode", {**shapes, "batch": 8},
                         "meshfp")
    assert k1 != key_for("qwen2.5-3b", "decode", shapes, "other-mesh")


def test_key_for_normalizes_smoke_suffix():
    """Smoke-shrunk configs record AND replay under the base arch — the
    one normalization point shared by record, serve, and the replayer."""
    assert key_arch("qwen2.5-3b-smoke") == "qwen2.5-3b"
    assert key_for("qwen2.5-3b-smoke", "prefill", {}, "m") == \
        key_for("qwen2.5-3b", "prefill", {}, "m")


# --------------------------------------------------------------- store ----
def test_store_roundtrip_dedup_and_gc():
    rec = synthetic_recording()
    with tempfile.TemporaryDirectory() as d:
        store, svc = make_registry(d)
        s1 = svc.publish("a/b/c", rec)
        assert svc.fetch_bytes("a/b/c") == rec.to_bytes()
        assert s1["chunks_new"] > 3 and s1["chunks_reused"] == 0

        # identical re-publish: every chunk deduplicated by content address
        s2 = svc.publish("a/b/c", rec)
        assert s2["chunks_new"] == 0
        assert s2["chunks_reused"] == s1["chunks_new"]
        assert s2["version"] == 2

        # a different key sharing the payload reuses its chunks too
        rec2 = Recording(dict(rec.manifest, name="other"), rec.payload,
                         rec.trees).sign_with(KEY)
        s3 = svc.publish("a/b/other", rec2)
        # payload + trees chunks shared; only manifest + signature are new
        assert s3["chunks_reused"] == s1["chunks_new"] - 2
        assert s3["chunks_new"] == 2

        # delete + gc drops chunks referenced by no entry
        store.delete("a/b/other")
        store.delete("a/b/c")
        assert store.gc() > 0
        with pytest.raises(RegistryMissError):
            store.get("a/b/c")


def test_store_reverifies_chunks_on_every_read():
    rec = synthetic_recording()
    with tempfile.TemporaryDirectory() as d:
        store, svc = make_registry(d)
        svc.publish("k", rec)
        digest = store.entry("k")["chunks"][1]["d"]
        path = store._chunk_path(digest)
        blob = bytearray(open(path, "rb").read())
        blob[len(blob) // 2] ^= 0x5A
        open(path, "wb").write(bytes(blob))
        with pytest.raises(RegistryIntegrityError):
            store.get("k")


def test_store_index_signature_enforced():
    rec = synthetic_recording()
    with tempfile.TemporaryDirectory() as d:
        store, svc = make_registry(d)
        svc.publish("k", rec)
        # on-disk index tamper: flipping a byte breaks the HMAC at load
        idx = os.path.join(d, "index.msgpack")
        blob = bytearray(open(idx, "rb").read())
        blob[len(blob) // 3] ^= 0xFF
        open(idx, "wb").write(bytes(blob))
        with pytest.raises((RegistryIntegrityError, TamperedRecordingError)):
            RecordingStore(d, key=KEY)
        # in-memory entry mutation: caught by the per-read signature check
        store2, _ = make_registry()
        _, svc2 = store2, RegistryService(store2, signing_key=KEY)
        svc2.publish("k", rec)
        store2._entries["k"]["total"] += 1
        with pytest.raises(RegistryIntegrityError):
            store2.get("k")


def test_shared_root_publishes_merge_across_store_handles():
    """Two store handles on one filesystem root (e.g. the record CLI and
    a long-lived serve process): a publish through one must not erase
    keys the other published meanwhile — mutations are read-modify-write
    against the on-disk index, not last-writer-wins."""
    rec = synthetic_recording(payload_bytes=40_000)
    with tempfile.TemporaryDirectory() as d:
        store_a, svc_a = make_registry(d)
        store_b, svc_b = make_registry(d)     # opened before any publish
        svc_a.publish("from/a/1", rec)
        svc_b.publish("from/b/1", rec)        # b must pick up a's entry
        assert store_b.has("from/a/1") and store_b.has("from/b/1")
        assert store_a.has("from/b/1")        # a re-reads the shared index
        assert svc_a.fetch_bytes("from/b/1") == rec.to_bytes()
        fresh, _ = make_registry(d)
        assert set(fresh.keys()) == {"from/a/1", "from/b/1"}


def test_lru_chunk_cache_is_byte_bounded():
    cache = LRUBytes(max_bytes=10_000)
    blobs = {f"d{i}": bytes(3_000) for i in range(8)}
    for dg, b in blobs.items():
        cache.put(dg, b)
    assert cache.nbytes <= 10_000
    assert cache.stats["evictions"] >= 4
    assert "d7" in cache and "d0" not in cache      # LRU order
    cache.get("d6")
    cache.put("dx", bytes(3_000))                   # evicts d5, not d6
    assert "d6" in cache and "d5" not in cache


# ------------------------------------------------- single-flight lease ----
def test_single_flight_eight_concurrent_misses_one_record():
    """Acceptance: 8 concurrent misses on one key cause exactly ONE
    record() call, and all 8 clients end with the same verified bytes."""
    _store, svc = make_registry()
    reg_key = key_for("arch", "decode", {"batch": 8}, "mesh")
    record_calls = []
    gate = threading.Barrier(8)

    def record_fn():
        record_calls.append(threading.get_ident())

        def fn(x):
            return x + 1.0

        rec = record(reg_key, fn,
                     (jax.ShapeDtypeStruct((4,), jnp.float32),))
        return rec.sign_with(KEY)

    results = [None] * 8
    errors = []

    def client_thread(i):
        try:
            gate.wait()        # maximize the race on the lease
            cl = RegistryClient(svc, netem=NetworkEmulator(WIFI), key=KEY)
            results[i] = cl.fetch(reg_key, record_fn=record_fn)
        except Exception as e:   # surfaced below; never swallow in-thread
            errors.append(e)

    threads = [threading.Thread(target=client_thread, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(record_calls) == 1                   # exactly one record()
    assert svc.stats["records"] == 1
    assert all(r == results[0] for r in results)    # same bytes, all 8
    for blob in results:
        Recording.from_bytes(blob, KEY)             # each verifies


def test_record_on_miss_failure_propagates_to_waiters():
    _store, svc = make_registry()

    def boom():
        raise RuntimeError("compile exploded")

    with pytest.raises(RuntimeError):
        svc.get_or_record("k", boom)
    assert not svc._leases                          # lease released
    with pytest.raises(RegistryMissError):
        svc.get_or_record("k", None)


# -------------------------------------------------------------- client ----
def test_client_resumable_fetch_and_byte_accounting():
    rec = synthetic_recording(payload_bytes=6 * 32 * 1024)
    _store, svc = make_registry(chunk_size=32 * 1024)
    svc.publish("k", rec)
    total_chunks = len(svc.entry("k")["chunks"])
    total_comp = sum(c["c"] for c in svc.entry("k")["chunks"])

    net = NetworkEmulator(WIFI)
    cl = RegistryClient(svc, netem=net, key=KEY)
    with pytest.raises(FetchInterrupted):
        cl.fetch("k", interrupt_after=2)
    assert cl.stats["chunks_fetched"] == 2
    partial_rx = net.bytes_received
    assert partial_rx < total_comp

    blob = cl.fetch("k")                            # resume: remainder only
    assert blob == rec.to_bytes()
    assert cl.stats["chunks_fetched"] == total_chunks
    assert cl.stats["chunk_bytes_fetched"] == total_comp
    # all compressed bytes crossed the wire exactly once (plus index RPCs
    # and the transparency-log proof the completed fetch verified)
    proof_rx = cl.stats["proof_bytes"]
    assert cl.stats["proofs_verified"] == 1
    chunk_rx = net.bytes_received - 2 * (64 + 48 * total_chunks) - proof_rx
    assert chunk_rx == total_comp

    # a second fetch is free on the wire: every chunk is cached locally —
    # only the index RPC plus a fresh (async-billed) inclusion proof
    net.reset()
    assert cl.fetch("k") == blob
    proof_rx2 = cl.stats["proof_bytes"] - proof_rx
    assert net.bytes_received == 64 + 48 * total_chunks + proof_rx2
    assert net.round_trips == 1                     # proofs add no RTT


def test_record_and_serve_derive_identical_decode_keys():
    """seq does not shape the decode step, so it must not enter decode
    identity — otherwise the record CLI (seq=32) and serve (rec_seq=16)
    would never key-match and every boot would re-record."""
    from repro.launch.record import static_meta_for
    s_record = static_meta_for("decode", cache_len=128, block_k=8, batch=4,
                               seq=32)
    s_serve = static_meta_for("decode", cache_len=128, block_k=8, batch=4,
                              seq=16)
    assert s_record == s_serve
    assert key_for("a", "decode", s_record, "m") == \
        key_for("a", "decode", s_serve, "m")
    # prefill IS seq-shaped: different seq, different key
    p32 = static_meta_for("prefill", cache_len=128, block_k=8, batch=1,
                          seq=32)
    p16 = static_meta_for("prefill", cache_len=128, block_k=8, batch=1,
                          seq=16)
    assert key_for("a", "prefill", p32, "m") != \
        key_for("a", "prefill", p16, "m")


def test_client_bills_chunks_evicted_mid_fetch():
    """A cache smaller than the recording forces refetches during
    reassembly — those bytes must be billed, not pulled for free."""
    rec = synthetic_recording(payload_bytes=8 * 32 * 1024)
    _store, svc = make_registry(chunk_size=32 * 1024)
    svc.publish("k", rec)
    total_comp = sum(c["c"] for c in svc.entry("k")["chunks"])
    net = NetworkEmulator(WIFI)
    cl = RegistryClient(svc, netem=net, key=KEY, cache_bytes=2 * 32 * 1024)
    assert cl.fetch("k") == rec.to_bytes()
    assert cl.stats["chunks_refetched"] > 0
    # wire bytes cover the full download AND every evicted-chunk refetch
    index_rx = 64 + 48 * len(svc.entry("k")["chunks"])
    assert net.bytes_received >= index_rx + total_comp + \
        cl.stats["chunks_refetched"]    # refetched chunks are >= 1 B each


def test_client_miss_without_record_fn():
    _store, svc = make_registry()
    cl = RegistryClient(svc, netem=NetworkEmulator(WIFI), key=KEY)
    with pytest.raises(RegistryMissError):
        cl.fetch("nope")


def test_delta_republish_ships_and_fetches_only_changed_chunks():
    """A config-tweak re-record delta-publishes (DeltaSync) only changed
    parts, and a client holding v1 refetches only the delta."""
    rec = synthetic_recording(payload_bytes=5 * 32 * 1024)
    _store, svc = make_registry(chunk_size=32 * 1024)
    s1 = svc.publish("k", rec)
    net = NetworkEmulator(WIFI)
    cl = RegistryClient(svc, netem=net, key=KEY)
    cl.fetch("k")

    rec2 = Recording(dict(rec.manifest, static={"tweak": 1}), rec.payload,
                     rec.trees).sign_with(KEY)
    s2 = svc.publish("k", rec2)
    assert s2["wire_bytes"] < s1["wire_bytes"] // 10   # manifest+sig only
    assert s2["chunks_reused"] >= 5                    # payload untouched

    net.reset()
    blob2 = cl.fetch("k")
    assert blob2 == rec2.to_bytes()
    chunk_rx = net.bytes_received - (64 + 48 * len(svc.entry("k")["chunks"]))
    # (chunk_rx still includes the ~200B transparency proof — well inside
    # the delta bound)
    assert chunk_rx < s1["full_bytes"] // 10           # delta fetch


def test_warm_handoff_into_replayer(real_recording):
    _store, svc = make_registry()
    reg_key = key_for("unit", "tanh", {"n": 16}, "mesh")
    svc.publish(reg_key, real_recording)
    cl = RegistryClient(svc, netem=NetworkEmulator(WIFI), key=KEY)
    rp = Replayer(key=KEY)
    names = cl.into_replayer(rp, [reg_key])
    assert names == [reg_key] and reg_key in rp
    assert rp.stats["executions"] == 1                 # warmed
    x = jnp.linspace(-1, 1, 16)
    np.testing.assert_allclose(np.asarray(rp.execute(reg_key, x)),
                               np.tanh(np.asarray(x)) * 2.0, rtol=1e-6)


# ------------------------------------------------------ trust boundary ----
SIDE_EFFECTS = []


class _Evil:
    def __reduce__(self):
        return (SIDE_EFFECTS.append, ("pwned",))


def test_signature_verified_before_any_unpickle():
    """An attacker-signed recording with a malicious pickle in trees must
    be rejected by the HMAC check BEFORE pickle.loads can run."""
    SIDE_EFFECTS.clear()
    evil = Recording({"name": "evil"}, b"payload",
                     pickle.dumps(_Evil())).sign_with(b"attacker-key")
    with pytest.raises(TamperedRecordingError):
        Replayer(key=KEY).load(evil.to_bytes())
    assert SIDE_EFFECTS == []

    # the service refuses to even publish a foreign-signed recording
    _store, svc = make_registry()
    with pytest.raises(TamperedRecordingError):
        svc.publish("evil", evil)

    # and a store-side swap of the trees chunk is caught by the client's
    # verification chain (chunk digests + HMAC), still before unpickling
    good = synthetic_recording()
    store2, svc2 = make_registry()
    svc2.publish("k", good)
    trees_row = next(c for c in store2.entry("k")["chunks"]
                     if c["part"] == "trees")
    store2._mem_chunks[trees_row["d"]] = b"not-zlib-not-signed"
    cl = RegistryClient(svc2, netem=None, key=KEY)
    with pytest.raises(TamperedRecordingError):
        cl.fetch("k")
    assert SIDE_EFFECTS == []


def test_unsigned_load_requires_explicit_opt_in(real_recording):
    blob = real_recording.to_bytes()
    with pytest.raises(UnverifiedRecordingError):
        Recording.from_bytes(blob)
    with pytest.raises(UnverifiedRecordingError):
        Replayer()                                  # no key, no opt-in
    rec = Recording.from_bytes(blob, allow_unsigned=True)   # explicit
    assert rec.manifest["name"] == real_recording.manifest["name"]
    rp = Replayer(key=None, allow_unsigned=True)
    assert rp.load(blob) == real_recording.manifest["name"]


# -------------------------------------------------- serve integration ----
def test_engine_boots_from_registry_with_record_on_miss():
    """build_engine(--from-registry --record-on-miss): first boot records
    through the single-flight lease; second boot is a pure registry hit
    (no record calls, recordings fetched + warmed into the Replayer)."""
    from repro.configs import get_config, smoke_shrink
    from repro.launch.serve import build_engine
    from repro.models import model as M

    cfg = smoke_shrink(get_config("cody-mnist"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as d:
        outs = {}
        for boot in ("cold", "warm"):
            net = NetworkEmulator(WIFI)
            eng = build_engine(
                cfg, n_slots=2, cache_len=64, block_k=4, eos_id=2,
                params=params, registry_dir=d, record_on_miss=True,
                key=KEY, netem=net, speculate=False, pipeline_depth=1)
            plen = eng.fixed_prompt_len
            assert plen is not None
            for _ in range(2):
                eng.submit(list(rng.integers(3, cfg.vocab_size, plen)), 6)
            outs[boot] = eng.run()
            stats = dict(eng.registry_client.stats)
            if boot == "cold":
                assert stats["recording_round_trips"] == 2   # prefill+decode
                rng = np.random.default_rng(0)               # same prompts
            else:
                assert stats.get("recording_round_trips", 0) == 0
                assert stats["registry_hits"] == 2
        # same prompts replayed from the registry: identical tokens
        assert outs["cold"] == outs["warm"]
