"""Fleet-scale replay serving (ISSUE 8): deterministic open-loop
traffic, placement policies + admission control, live-fleet
bit-exactness vs solo serving, autoscaling (scale-up, drain-then-retire),
bit-exact cross-replica migration, per-replica billing isolation,
registry read-replica effectiveness + store LRU counters, and the
same-seed byte-identity of the fleet bench artifact."""
import json

import numpy as np
import pytest

from repro.api import Workspace
from repro.fleet import Arrival, LoadBalancer, OpenLoopTraffic, TenantMix
from repro.obs.schema import (SchemaError, check_fleet_stats,
                              check_registry_store_stats,
                              check_workspace_report)

KEY = b"fleet-test-key"
SHAPES = dict(cache_len=64, block_k=4, batch=2, prefill_batch=1, seq=8)


# ------------------------------------------------------------ traffic ----
def test_traffic_same_seed_byte_identical():
    """Two generators with the same mixes and seed must produce EQUAL
    arrival lists (the whole fleet determinism story rests on this)."""
    mixes = [TenantMix("a", 8.0, prompt_len=(4, 12), max_new=(2, 10)),
             TenantMix("b", 5.0, prompt_len=8, max_new=6)]
    kw = dict(seed=7, burst_every_s=1.0, burst_len_s=0.25, burst_x=4.0)
    one = OpenLoopTraffic(mixes, **kw).generate(5.0)
    two = OpenLoopTraffic(mixes, **kw).generate(5.0)
    assert one == two
    assert one != OpenLoopTraffic(mixes, **dict(kw, seed=8)).generate(5.0)
    assert all(0.0 <= a.t < 5.0 for a in one)
    assert [a.gid for a in one] == list(range(len(one)))      # arrival order
    assert sorted(one, key=lambda a: (a.t, a.tenant)) == one


def test_traffic_poisson_rate_and_burst_density():
    """Arrival counts track rate*horizon, and the thinned process really
    runs ``burst_x`` hotter inside burst windows."""
    tr = OpenLoopTraffic([TenantMix("a", 50.0)], seed=0,
                         burst_every_s=1.0, burst_len_s=0.25, burst_x=4.0)
    arrivals = tr.generate(40.0)
    # expected arrivals: 40s * (0.75*50 + 0.25*200) = 3500
    assert 3000 < len(arrivals) < 4000
    burst = sum(1 for a in arrivals if tr.in_burst(a.t))
    calm = len(arrivals) - burst
    # per-second density ratio should approximate burst_x = 4
    ratio = (burst / 10.0) / (calm / 30.0)
    assert 3.0 < ratio < 5.0
    # plain Poisson when burst knobs are off
    plain = OpenLoopTraffic([TenantMix("a", 50.0)], seed=0).generate(40.0)
    assert 1700 < len(plain) < 2300


def test_traffic_tenant_substreams_independent():
    """Adding tenant B must not perturb tenant A's arrivals: per-tenant
    substreams are seeded ``(seed, idx)``, not shared."""
    a_only = OpenLoopTraffic([TenantMix("a", 10.0)], seed=3).generate(4.0)
    both = OpenLoopTraffic([TenantMix("a", 10.0), TenantMix("b", 7.0)],
                           seed=3).generate(4.0)
    a_of_both = [(x.t, x.prompt, x.max_new) for x in both
                 if x.tenant == "a"]
    assert [(x.t, x.prompt, x.max_new) for x in a_only] == a_of_both


def test_traffic_validates_inputs():
    with pytest.raises(ValueError, match="at least one"):
        OpenLoopTraffic([])
    with pytest.raises(ValueError, match="duplicate"):
        OpenLoopTraffic([TenantMix("a", 1.0), TenantMix("a", 2.0)])
    with pytest.raises(ValueError, match="burst_x"):
        OpenLoopTraffic([TenantMix("a", 1.0)], burst_x=0.5)


# ----------------------------------------------------------- balancer ----
class _FakeReplica:
    def __init__(self, name, cap=2, tenants=("a", "b"), load=0):
        self.name = name
        self.cap = cap
        self._tenants = tenants
        self.placed = []
        self._load = load

    def can_accept(self, tenant):
        return tenant in self._tenants and \
            self._load + len(self.placed) < self.cap

    def load(self):
        return self._load + len(self.placed)

    def submit(self, arrival):
        self.placed.append(arrival)


def _arr(gid, tenant="a", t=0.0):
    return Arrival(gid, t, tenant, (3, 4, 5), 4)


def test_balancer_round_robin_rotates():
    lb = LoadBalancer("round_robin")
    reps = [_FakeReplica("r0", cap=9), _FakeReplica("r1", cap=9)]
    for g in range(4):
        lb.offer(_arr(g))
    lb.dispatch(reps)
    assert [len(r.placed) for r in reps] == [2, 2]
    assert [a.gid for a in reps[0].placed] == [0, 2]


def test_balancer_least_loaded_prefers_min_with_name_tiebreak():
    lb = LoadBalancer("least_loaded")
    reps = [_FakeReplica("r0", cap=9, load=3),
            _FakeReplica("r1", cap=9, load=1),
            _FakeReplica("r2", cap=9, load=1)]
    lb.offer(_arr(0))
    lb.dispatch(reps)
    assert len(reps[1].placed) == 1        # min load, name-tiebroken to r1
    assert not reps[0].placed and not reps[2].placed


def test_balancer_cache_affinity_sticky_waits_and_repins():
    lb = LoadBalancer("cache_affinity")
    r0, r1 = _FakeReplica("r0", cap=2), _FakeReplica("r1", cap=2)
    lb.offer(_arr(0, "a"))
    lb.dispatch([r0, r1])
    assert len(r0.placed) == 1             # first placement: least-loaded
    # pin is sticky even when the other replica is emptier
    lb.offer(_arr(1, "a"))
    lb.dispatch([r0, r1])
    assert len(r0.placed) == 2 and not r1.placed
    # pinned replica full -> the arrival WAITS (no spill to r1)
    lb.offer(_arr(2, "a"))
    lb.dispatch([r0, r1])
    assert lb.queue_depth() == 1 and not r1.placed
    # retiring the pinned replica drops the pin; the tenant re-pins
    lb.forget("r0")
    lb.dispatch([r1])
    assert len(r1.placed) == 1 and lb.queue_depth() == 0


def test_balancer_admission_rejects_at_queue_limit():
    lb = LoadBalancer("round_robin", queue_limit=2)
    admitted = [lb.offer(_arr(g)) for g in range(5)]
    assert admitted == [True, True, False, False, False]
    snap = lb.snapshot()
    assert snap["offered"] == 5 and snap["rejected"] == 3
    assert snap["queue_depth"] == 2 == snap["queue_hwm"]


def test_balancer_fifo_with_skip_no_head_of_line_blocking():
    """An arrival whose tenant no replica can accept stays queued without
    blocking later arrivals for other tenants."""
    lb = LoadBalancer("round_robin")
    only_b = _FakeReplica("r0", cap=4, tenants=("b",))
    lb.offer(_arr(0, "a"))
    lb.offer(_arr(1, "b"))
    placed = lb.dispatch([only_b])
    assert [(a.gid, r.name) for a, r in placed] == [(1, "r0")]
    assert [a.gid for a in lb.queue] == [0]
    with pytest.raises(ValueError, match="unknown policy"):
        LoadBalancer("random")


# ----------------------------------------------------- live fleet e2e ----
@pytest.fixture(scope="module")
def live_ws():
    """One live workspace + workloads shared by the fleet e2e tests (the
    memoized LiveChannel makes every replica share compiled steps)."""
    ws = Workspace()
    wl_q = ws.workload("qwen2.5-3b", **SHAPES)
    wl_x = ws.workload("xlstm-350m", **SHAPES)
    return ws, wl_q, wl_x


def _solo_outputs(workloads, arrivals, seed=0):
    """Reference: each arrival served ALONE through the same recordings
    and params the fleet streams use (stream i gets seed + i)."""
    out = {}
    for i, wl in enumerate(workloads):
        eng = wl.engine(seed=seed + i)
        for a in arrivals:
            if a.tenant != wl.cfg.name:
                continue
            rid = eng.submit(list(a.prompt), a.max_new)
            out[a.gid] = list(eng.run()[rid])
    return out


def test_live_fleet_bit_exact_vs_solo_and_report_schema(live_ws):
    """Tentpole acceptance (live mode): a 2-replica fleet over two model
    families serves open-loop traffic bit-exactly vs solo serving, and
    the workspace report carries the pinned fleet/store shapes."""
    ws, wl_q, wl_x = live_ws
    pool, _ = ws.fleet([wl_q, wl_x], replicas=2, policy="least_loaded",
                       name="lb")
    mixes = [TenantMix(wl.cfg.name, 8.0, prompt_len=(4, 12),
                       max_new=(4, 12), vocab=min(wl.cfg.vocab_size, 256))
             for wl in (wl_q, wl_x)]
    arrivals = OpenLoopTraffic(mixes, seed=11, burst_every_s=0.5,
                               burst_len_s=0.1, burst_x=3.0).generate(1.0)
    outputs = pool.run(arrivals)
    assert len(outputs) == len(arrivals) and not pool.failed
    assert outputs == _solo_outputs((wl_q, wl_x), arrivals)
    # both replicas actually served, and latency got observed per tenant
    assert all(r.served > 0 for r in pool.replicas)
    for wl in (wl_q, wl_x):
        q = ws.metrics.quantiles("fleet_request_latency_s", pool="lb",
                                 tenant=wl.cfg.name)
        assert q is not None and q["p50"] <= q["p99"] <= q["p999"]
    stats = check_fleet_stats(pool.stats())
    assert stats["served"] == len(arrivals)
    assert stats["balancer"]["placed"] == len(arrivals)
    rep = check_workspace_report(ws.report())
    assert any(f["name"] == "lb" for f in rep["fleet"])
    with pytest.raises(SchemaError, match="missing fields"):
        check_fleet_stats({"name": "broken"})


def test_fleet_admission_sheds_load_deterministically(live_ws):
    """Open-loop overload with a queue limit: some arrivals are rejected
    (never served), every admitted one completes, and the accounting
    adds up."""
    ws, wl_q, _ = live_ws
    pool, _ = ws.fleet([wl_q], replicas=1, policy="round_robin",
                       name="shed", pending_limit=2, queue_limit=3)
    arrivals = OpenLoopTraffic(
        [TenantMix(wl_q.cfg.name, 200.0, prompt_len=(4, 8), max_new=8,
                   vocab=min(wl_q.cfg.vocab_size, 256))],
        seed=5).generate(0.2)
    outputs = pool.run(arrivals)
    snap = pool.stats()["balancer"]
    assert snap["rejected"] > 0
    assert snap["placed"] + snap["rejected"] == snap["offered"] == \
        len(arrivals)
    assert len(outputs) == snap["placed"]
    # rejected arrivals never appear in outputs; admitted ones are
    # bit-exact vs solo (load shedding protects, it does not corrupt)
    admitted = [a for a in arrivals if a.gid in outputs]
    assert outputs == _solo_outputs((wl_q,), admitted)


def test_fleet_autoscales_up_then_drains_and_retires(live_ws):
    """Sustained queue depth boots a new replica (ready after the FIXED
    boot_ticks delay); once the backlog clears, the extra replica drains
    and retires while the first is still serving."""
    ws, wl_q, _ = live_ws
    pool, _ = ws.fleet([wl_q], replicas=1, policy="round_robin",
                       name="auto", pending_limit=6, autoscale=True,
                       queue_high=4, sustain_ticks=2, idle_ticks=2,
                       boot_ticks=2, min_replicas=1, max_replicas=3)
    tenant = wl_q.cfg.name
    rng = np.random.default_rng(9)
    prompt = lambda: tuple(
        int(x) for x in rng.integers(3, min(wl_q.cfg.vocab_size, 256), 6))
    # 6 long requests saturate replica 0; 8 short ones pile up the queue
    arrivals = [Arrival(g, 0.0, tenant, prompt(), 32) for g in range(6)]
    arrivals += [Arrival(6 + g, 0.0, tenant, prompt(), 2) for g in range(8)]
    outputs = pool.run(arrivals)
    assert len(outputs) == len(arrivals) and not pool.failed
    stats = check_fleet_stats(pool.stats())
    assert stats["autoscale"]["scale_ups"] >= 1
    assert stats["autoscale"]["retired"] >= 1
    assert len(pool.replicas) >= 2
    scaled = pool.replicas[1]
    assert scaled.ready_at > 0.0           # paid the boot_ticks delay
    assert scaled.served > 0 and scaled.retired
    assert not pool.replicas[0].retired    # min_replicas floor held


def test_migration_preempt_on_a_resume_on_b_bit_exact(live_ws):
    """Satellite: a tenant's in-flight requests preempted on replica A
    mid-decode and adopted by replica B finish with exactly the tokens a
    solo engine produces."""
    ws, wl_q, _ = live_ws
    pool, _ = ws.fleet([wl_q], replicas=2, policy="round_robin",
                       name="mig")
    tenant = wl_q.cfg.name
    a, b = pool.replicas
    rng = np.random.default_rng(13)
    arrivals = [
        Arrival(g, 0.0, tenant,
                tuple(int(x) for x in
                      rng.integers(3, min(wl_q.cfg.vocab_size, 256), 5)),
                16)
        for g in range(3)]
    for x in arrivals:
        a.submit(x)
    for _ in range(3):                     # partial decode on A
        a.step()
    assert a.load() == 3
    moved = pool.migrate(tenant, a.name, b.name)
    assert moved == 3 and a.load() == 0 and b.load() == 3
    assert not a.has_work()
    steps = 0
    while b.has_work():
        b.step()
        steps += 1
        assert steps < 500
    b.finish()
    done = {gid: toks for gid, _, toks, failed in b.collect_done()
            if not failed}
    assert done == _solo_outputs((wl_q,), arrivals)
    assert pool.stats()["migrations"] == 1
    assert b.stats["adopted"] == 3 and a.stats["released"] == 3


# --------------------------------------------- registry-backed fleets ----
@pytest.fixture(scope="module")
def registry_ws():
    """An in-memory registry with one published recording per kind (the
    cheap cody-mnist family) for billing/read-replica tests."""
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    wl = ws.workload("cody-mnist", **SHAPES)
    for kind in ("prefill", "decode"):
        wl.publish(wl.record(kind))
    return ws, wl


def test_per_replica_billing_isolation(registry_ws):
    """Satellite (billing aliasing fix): clients from ``new_client`` are
    fully independent — one client's fetch bills ITS emulator and ITS
    stats, and the shared workspace client is never even created."""
    ws, wl = registry_ws
    n1, n2 = ws.fresh_netem(), ws.fresh_netem()
    c1, c2 = ws.new_client(netem=n1), ws.new_client(netem=n2)
    base_t1, base_t2 = n1.virtual_time_s, n2.virtual_time_s
    c1.fetch(wl.key("prefill"))
    assert c1.stats["registry_hits"] == 1
    assert c1.stats["chunks_fetched"] > 0
    assert n1.virtual_time_s > base_t1           # c1 paid on its own span
    # NOTHING leaked onto the sibling client or its emulator
    assert c2.stats["registry_hits"] == 0
    assert c2.stats["chunks_fetched"] == 0
    assert n2.virtual_time_s == base_t2
    # c2's own fetch costs the same fresh-cache price as c1's (no shared
    # chunk cache silently discounting it)
    c2.fetch(wl.key("prefill"))
    assert c2.stats["chunks_fetched"] == c1.stats["chunks_fetched"]
    assert ws.report()["registry_client"] == {}  # shared client: unused


def test_read_replica_absorbs_regional_traffic(registry_ws):
    """Satellites (read-replicas + store LRU counters): the first fetch
    in a region pulls each chunk from the primary once; later fetches in
    that region hit the regional cache and the primary's ``chunk_reads``
    stays flat.  A second region re-pulls, but the store's own LRU now
    serves the chunks (hits, no new disk reads)."""
    ws, wl = registry_ws
    key = wl.key("prefill")
    rr0 = ws.read_replica("r0")
    reads0 = ws.store.summary()["chunk_reads"]
    c1 = ws.new_client(netem=ws.fresh_netem(), region="r0")
    c1.fetch(key)
    pulls = rr0.summary()["chunk_pulls"]
    assert pulls > 0
    delta = ws.store.summary()["chunk_reads"] - reads0
    assert 0 <= delta <= pulls             # store LRU may absorb some
    # same region, second client: served regionally, primary untouched
    mid = ws.store.summary()["chunk_reads"]
    c2 = ws.new_client(netem=ws.fresh_netem(), region="r0")
    c2.fetch(key)
    assert rr0.summary()["chunk_pulls"] == pulls
    assert ws.store.summary()["chunk_reads"] == mid
    assert rr0.summary()["cache"]["hits"] >= pulls
    # different region: pulls again, but the store LRU serves it (hits
    # counted through repro.obs.metrics, no extra chunk_reads)
    hits0 = ws.store.summary()["cache"]["hits"]
    c3 = ws.new_client(netem=ws.fresh_netem(), region="r1")
    c3.fetch(key)
    assert ws.read_replica("r1").summary()["chunk_pulls"] == pulls
    assert ws.store.summary()["chunk_reads"] == mid
    assert ws.store.summary()["cache"]["hits"] > hits0
    counters = ws.metrics.snapshot()["counters"]
    assert counters.get("registry_cache_hits{scope=store}", 0) > 0
    assert counters.get("registry_cache_misses{region=r1}", 0) > 0
    store_stats = check_registry_store_stats(
        ws.report()["registry_store"])
    assert [r["region"] for r in store_stats["read_replicas"]] == \
        ["r0", "r1"]


def test_registry_fleet_boots_warm_per_replica_spans(registry_ws):
    """A registry fleet's replicas each boot on their OWN netem span
    (warm: registry hits, no recording), serve bit-exactly vs solo, and
    regional read-replicas split the chunk traffic."""
    ws, wl = registry_ws
    unique = len({c["d"] for kind in ("prefill", "decode")
                  for c in ws.store.entry(wl.key(kind))["chunks"]})
    reads_before = ws.store.summary()["chunk_reads"]
    pool, _ = ws.fleet([wl], replicas=2, policy="cache_affinity",
                       regions=2, name="warm")
    boots = [r.boot_virtual_s for r in pool.replicas]
    assert all(b > 0.0 for b in boots)     # each replica billed its boot
    assert [r.region for r in pool.replicas] == [0, 1]
    # booting 2 replicas in 2 regions did not 2x the primary disk reads:
    # each unique chunk leaves disk at most once (store LRU absorbs the
    # second region's pull), however many replicas boot
    assert ws.store.summary()["chunk_reads"] - reads_before <= unique
    arrivals = OpenLoopTraffic(
        [TenantMix(wl.cfg.name, 10.0, prompt_len=SHAPES["seq"], max_new=8,
                   vocab=min(wl.cfg.vocab_size, 256))],
        seed=2).generate(0.8)
    outputs = pool.run(arrivals)
    assert len(outputs) == len(arrivals) and not pool.failed
    assert outputs == _solo_outputs((wl,), arrivals)
    check_workspace_report(ws.report())


# ------------------------------------------------- bench determinism ----
def test_fleet_bench_same_seed_byte_identical(tmp_path):
    """Satellite: two same-seed bench runs produce byte-identical
    BENCH_fleet.json modulo the wall/boot fields (recording wall time and
    serialized executable sizes are the ONLY nondeterminism allowed)."""
    from benchmarks.fleet_bench import main as bench_main
    from benchmarks.fleet_bench import strip_nondeterministic
    from repro.obs.schema import check_bench_file
    paths = [tmp_path / f"BENCH_fleet.json.{i}" for i in (0, 1)]
    for p in paths:
        bench_main(quick=True, out_json=str(p))
    one, two = (json.loads(p.read_text()) for p in paths)
    assert one["bit_exact_vs_solo"] is True
    assert one["warm_boot_cheaper_than_cold"] is True
    assert "wall_s" in one and "registry_boot" in one
    stripped = strip_nondeterministic(one)
    assert "wall_s" not in stripped and "registry_boot" not in stripped
    assert json.dumps(stripped, sort_keys=True) == \
        json.dumps(strip_nondeterministic(two), sort_keys=True)
    # and the artifact passes the CI schema gate
    gate = tmp_path / "BENCH_fleet.json"
    gate.write_text(json.dumps(one))
    assert "schema ok" in check_bench_file(str(gate))
