import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device;
# multi-device tests spawn subprocesses that set their own flags.

# Optional-hypothesis shim shared by test modules: property tests skip when
# hypothesis is absent (this container), run for real in CI.
try:
    from hypothesis import given, settings, strategies as st   # noqa: F401
except ImportError:
    import pytest

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()
    settings = lambda *a, **k: (lambda fn: fn)
    given = lambda *a, **k: pytest.mark.skip(
        reason="hypothesis not installed")
