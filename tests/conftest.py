import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device;
# multi-device tests spawn subprocesses that set their own flags.
