"""Async decode pipeline: frontier-only host syncs, speculation rollback
(forced EOS mid-pipeline), batched prefill equivalence, and the replayer's
argument validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_shrink
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, cache_batch_axes_for
from repro.sharding import rules_for
from repro.training import steps as ST

BLOCK_K = 4
CACHE_LEN = 96


def _cfg(arch="cody-mnist"):
    return smoke_shrink(get_config(arch))


def _make_engine(cfg, params, *, speculate, depth, decode_wrap=None,
                 batched=True, n_slots=2, netem=None):
    rules = rules_for("serve", make_host_mesh(model=1).axis_names)
    prefill = jax.jit(ST.make_prefill_step(cfg, rules, CACHE_LEN))
    batched_prefill = jax.jit(
        ST.make_batched_prefill_step(cfg, rules, CACHE_LEN)) \
        if batched else None
    decode = jax.jit(
        ST.make_fused_decode_step(cfg, rules, k=BLOCK_K, eos_id=2),
        donate_argnums=(3,))
    if decode_wrap is not None:
        decode = decode_wrap(decode)
    return Engine(params, prefill, decode, n_slots=n_slots,
                  cache_len=CACHE_LEN, block_k=BLOCK_K, eos_id=2,
                  init_caches_fn=lambda: M.init_cache(cfg, n_slots,
                                                      CACHE_LEN),
                  cache_batch_axes=cache_batch_axes_for(cfg), netem=netem,
                  speculate=speculate, pipeline_depth=depth,
                  batched_prefill_fn=batched_prefill)


def _submit_workload(eng, cfg, n=5, max_new=14, seed=7):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        plen = int(rng.integers(4, 12))
        eng.submit(list(rng.integers(3, cfg.vocab_size, plen)), max_new)


@pytest.mark.parametrize("arch", ["cody-mnist", "qwen2.5-3b"])
def test_pipeline_bit_exact_vs_sync(arch):
    """Acceptance: speculative pipelined and synchronous modes produce
    identical token streams after validate()."""
    cfg = _cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng_sync = _make_engine(cfg, params, speculate=False, depth=1)
    _submit_workload(eng_sync, cfg)
    outs_sync = eng_sync.run()
    eng_spec = _make_engine(cfg, params, speculate=True, depth=4)
    _submit_workload(eng_spec, cfg)
    outs_spec = eng_spec.run()
    assert outs_sync == outs_spec
    assert eng_spec.stats["spec_blocks"] > 0
    assert eng_spec.stats["host_syncs"] < eng_sync.stats["host_syncs"]
    # every request validated to its full tail at the final frontier
    for req in eng_spec.requests.values():
        assert req.done and req.committed == len(req.generated)


def _forced_eos_wrap(trigger_pos, eos_id=2):
    """Wrap a fused decode fn so slot 0 emits EOS once its input position
    reaches ``trigger_pos``.  Pure function of the block inputs => fires at
    the same logical block in speculative, synchronous, and re-executed
    runs; stays device-side (no host sync in the wrapper)."""
    def wrap(base):
        def fn(params, toks, pos, caches):
            out, caches = base(params, toks, pos, caches)
            trig = pos[0] >= trigger_pos
            tokens = out["tokens"].at[0, -1].set(
                jnp.where(trig, eos_id, out["tokens"][0, -1]))
            done = out["done"].at[0].set(out["done"][0] | trig)
            return {"tokens": tokens, "pos": out["pos"], "done": done}, \
                caches
        return fn
    return wrap


def test_forced_eos_mispredict_rolls_back_to_sync_stream():
    """Satellite: inject a forced EOS mid-pipeline; the mispredict path
    must roll back and still produce the synchronous token stream."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    # prompts are 6 tokens; trigger deep enough that the EOS lands inside a
    # speculative pipeline window (after warm-up sync blocks)
    wrap = _forced_eos_wrap(trigger_pos=6 + 4 * BLOCK_K)
    runs = {}
    for mode, (spec, depth) in {"sync": (False, 1),
                                "spec": (True, 4)}.items():
        eng = _make_engine(cfg, params, speculate=spec, depth=depth,
                           decode_wrap=wrap)
        rng = np.random.default_rng(3)
        for _ in range(4):
            eng.submit(list(rng.integers(3, cfg.vocab_size, 6)), 28)
        runs[mode] = (eng.run(), eng)
    outs_sync, _ = runs["sync"]
    outs_spec, eng_spec = runs["spec"]
    assert eng_spec.stats["mispredicts"] >= 1
    assert outs_sync == outs_spec          # token-for-token, incl. tails
    # the forced EOS really ended a request early
    assert any(r.generated[-1] == 2 and len(r.generated) < 28
               for r in eng_spec.requests.values())


def test_mid_pipeline_admission_is_sound():
    """Regression: submitting a request while speculative blocks are in
    flight must drain the frontier before admission — the device chain
    re-seed reads host metastate, which is stale mid-pipeline."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(3, cfg.vocab_size, 7)) for _ in range(3)]
    outs = {}
    for mode, (spec, depth) in {"sync": (False, 1),
                                "spec": (True, 4)}.items():
        eng = _make_engine(cfg, params, speculate=spec, depth=depth,
                           n_slots=4)
        for p in prompts[:2]:
            eng.submit(p, 24)
        for _ in range(6):          # deep enough that blocks are in flight
            eng.step_block()
        eng.submit(prompts[2], 24)  # mid-pipeline admission
        outs[mode] = eng.run()
    assert outs["sync"] == outs["spec"]


def test_deeper_pipeline_fewer_host_syncs():
    """Acceptance: host-sync count drops ~1/validate_every with depth."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    outs, syncs = {}, {}
    for depth in (1, 4):
        eng = _make_engine(cfg, params, speculate=True, depth=depth)
        _submit_workload(eng, cfg, n=4, max_new=16)
        outs[depth] = eng.run()
        syncs[depth] = eng.stats["host_syncs"]
    assert outs[1] == outs[4]
    assert syncs[4] < syncs[1]


def test_batched_prefill_matches_per_request():
    """Grouped right-padded admission must not change any token: compare
    against the exact-shape per-request path on mixed prompt lengths."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    outs = {}
    for batched in (False, True):
        eng = _make_engine(cfg, params, speculate=False, depth=1,
                           batched=batched, n_slots=3)
        _submit_workload(eng, cfg, n=6, max_new=10, seed=11)
        outs[batched] = eng.run()
        if batched:
            # 3 slots admitted as a group -> fewer dispatches than requests
            assert eng.stats["prefill_dispatches"] < 6
    assert outs[False] == outs[True]


def test_replayer_validates_args_and_dispatches_on_avals():
    """Satellite: execute() rejects wrong shapes/dtypes with a clear error
    (not an XLA crash) and dispatches between same-name recordings on the
    argument avals."""
    from repro.core.recorder import record
    from repro.core.replay import ReplayArgumentError, Replayer

    key = b"k"
    fn = lambda x: x * 2.0
    rp = Replayer(key=key)
    for n in (4, 8):
        rec = record("double", fn,
                     (jax.ShapeDtypeStruct((n,), jnp.float32),))
        rec.sign_with(key)
        rp.load(rec.to_bytes(), name="double")
    # aval dispatch: both shapes execute through one logical name
    np.testing.assert_allclose(
        np.asarray(rp.execute("double", jnp.ones(4, jnp.float32))), 2.0)
    np.testing.assert_allclose(
        np.asarray(rp.execute("double", jnp.ones(8, jnp.float32))), 2.0)
    with pytest.raises(ReplayArgumentError) as ei:
        rp.execute("double", jnp.ones(5, jnp.float32))
    assert "float32[5]" in str(ei.value) and "recorded" in str(ei.value)
    with pytest.raises(ReplayArgumentError):
        rp.execute("double", jnp.ones(4, jnp.int32))   # dtype mismatch
    # warm path executes each variant once without error
    before = rp.stats["executions"]
    rp.warm("double")
    assert rp.stats["executions"] == before + 2


def test_replayer_dispatches_on_dtype_when_shapes_collide():
    """Satellite: two recordings of one workload sharing a SHAPE but
    differing in dtype must occupy distinct executable-cache entries —
    the aval signature includes the dtype, so dispatch picks the right
    executable and the error message names the near-miss."""
    from repro.core.recorder import record
    from repro.core.replay import ReplayArgumentError, Replayer

    key = b"k"
    rp = Replayer(key=key)
    for dt, scale in ((jnp.float32, 2.0), (jnp.int32, 3)):
        rec = record("scale", lambda x, scale=scale: x * scale,
                     (jax.ShapeDtypeStruct((4,), dt),))
        rec.sign_with(key)
        rp.load(rec.to_bytes(), name="scale")
    # same shape, different dtype -> different executable, right result
    np.testing.assert_allclose(
        np.asarray(rp.execute("scale", jnp.ones(4, jnp.float32))), 2.0)
    np.testing.assert_array_equal(
        np.asarray(rp.execute("scale", jnp.ones(4, jnp.int32))), 3)
    # a third dtype misses BOTH variants; the message points at the dtype
    # (the first differing leaf), not the shape
    with pytest.raises(ReplayArgumentError) as ei:
        rp.execute("scale", jnp.ones(4, jnp.float16))
    msg = str(ei.value)
    assert "float16[4]" in msg                      # what the caller sent
    assert "recorded" in msg and "first mismatch at leaf 0" in msg
    assert "float32[4]" in msg and "int32[4]" in msg  # both near-misses
