"""repro.obs: virtual-time tracing + metrics layer.

The two load-bearing guarantees (ISSUE-7 satellites):

  * **Determinism** — recording the same workload twice yields
    byte-identical virtual-time traces once wall timestamps are stripped
    (``to_json(strip_wall=True)``);
  * **Zero-cost when off** — a tracing-off run leaves every netem /
    session / replay counter bit-identical to a traced run (tracing only
    *reads* the virtual clock, never mutates accounting).

Plus the tracer/metrics unit surface (interval-union attribution,
clock-scope rebasing, nearest-rank quantiles, stable snapshot schema)
and the report/bench schema checker.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.netem import WIFI, NetworkEmulator
from repro.core.recorder import compile_artifact
from repro.core.recording import Recording
from repro.core.replay_passes import PlanExecutor, plan_for
from repro.obs import (NULL, Metrics, NullTracer, SchemaError, Tracer,
                       check_workspace_report, metric_key, traced)
from repro.obs.schema import check_bench_file, check_scheduler_stats
from repro.record import CloudDryrun, RecordingSession

JOBS = 16


def _tiny():
    return (lambda x: jnp.tanh(x) * 2.0,
            (jax.ShapeDtypeStruct((8,), jnp.float32),))


@pytest.fixture(scope="module")
def artifact():
    fn, spec = _tiny()
    return compile_artifact("t", fn, spec)


def _copy(rec):
    return Recording(dict(rec.manifest), rec.payload, rec.trees)


class FakeClock:
    """Hand-cranked virtual clock for tracer unit tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ------------------------------------------------------------ tracer unit --
def test_span_nesting_and_attribution_union():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("outer", "work"):
        clk.t = 2.0
        with tr.span("inner", "work"):
            clk.t = 5.0
        clk.t = 10.0
    # inner [2,5) nests inside outer [0,10): union is 10, not 13
    assert tr.attributed_s("work") == 10.0
    spans = tr.spans("work")
    assert [s["name"] for s in spans] == ["inner", "outer"]  # close order
    assert spans[1]["ts"] == 0.0 and spans[1]["dur"] == 10.0


def test_attribution_disjoint_and_since_mark():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("a", "t"):
        clk.t = 3.0
    clk.t = 10.0
    since = tr.mark()
    with tr.span("b", "t"):
        clk.t = 14.0
    assert tr.attributed_s("t") == 7.0            # [0,3) + [10,14)
    assert tr.attributed_s("t", since=since) == 4.0
    assert tr.attributed_s("other") == 0.0


def test_clock_scope_rebases_sequentially():
    """Two components with private emulators lay out end-to-end on the
    trace timeline instead of both starting at 0."""
    tr = Tracer()                                 # base clock: constant 0
    n1 = NetworkEmulator(WIFI)
    with tr.clock_scope(n1), tr.span("first", "record"):
        n1.round_trip()
    first = tr.spans("record")[0]
    assert first["ts"] == 0.0 and first["dur"] > 0.0
    n2 = NetworkEmulator(WIFI)                    # fresh clock, also at 0
    with tr.clock_scope(n2), tr.span("second", "record"):
        n2.round_trip()
    second = tr.spans("record")[1]
    assert second["ts"] == pytest.approx(first["dur"])  # rebased past first
    # None scope is a no-op, not an error
    with tr.clock_scope(None):
        assert tr.now() == 0.0


def test_chrome_trace_export_shape():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("s", "record", site="reg0"):
        clk.t = 1.5
    tr.instant("ping", "replay")
    tr.counter("depth", 3, "replay")
    doc = tr.chrome_trace(strip_wall=True)
    assert doc["metadata"]["clock"] == "virtual"
    evs = doc["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert [m["args"]["name"] for m in metas] == ["record", "replay"]
    span = next(e for e in evs if e["ph"] == "X")
    assert span["ts"] == 0.0 and span["dur"] == 1.5e6   # seconds -> us
    assert span["args"] == {"site": "reg0"}             # wall stripped
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t"
    ctr = next(e for e in evs if e["ph"] == "C")
    assert ctr["args"] == {"value": 3.0}
    # wall fields come back when not stripped
    wall = tr.chrome_trace(strip_wall=False)
    assert "wall_s" in next(e for e in wall["traceEvents"]
                            if e["ph"] == "X")["args"]


def test_null_tracer_is_falsy_noop():
    assert not NULL
    assert isinstance(NULL, NullTracer)
    assert NULL.mark() == 0 and NULL.now() == 0.0
    with NULL.span("x", "y"), NULL.clock_scope(None):
        pass
    NULL.instant("x")
    NULL.counter("x", 1)
    assert NULL.events == ()
    # traced() hands back a shared no-op context manager when off
    with traced(NULL, "x", "y", k=1):
        pass
    tr = Tracer(clock=FakeClock())
    with traced(tr, "x", "y"):
        pass
    assert len(tr.events) == 1


def test_summary_orders_by_virtual_time():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("small", "t"):
        clk.t = 1.0
    with tr.span("big", "t"):
        clk.t = 9.0
    rows = tr.summary()
    assert [r["name"] for r in rows] == ["big", "small"]
    assert rows[0]["virtual_s"] == 8.0 and rows[0]["count"] == 1
    assert "big" in tr.format_summary(top=1)
    assert "small" not in tr.format_summary(top=1)


# ----------------------------------------------------------- metrics unit --
def test_metric_key_sorts_labels():
    assert metric_key("lat", {}) == "lat"
    assert metric_key("lat", {"b": 1, "a": "x"}) == "lat{a=x,b=1}"


def test_histogram_nearest_rank_quantiles():
    m = Metrics()
    h = m.histogram("lat", stream="s0")
    for v in range(1, 101):                       # 1..100
        h.observe(float(v))
    q = m.quantiles("lat", stream="s0")
    assert q == {"p50": 50.0, "p99": 99.0, "p999": 100.0}
    assert h.quantile(1.0) == 100.0
    with pytest.raises(ValueError):
        h.quantile(0.0)
    # single observation: every quantile is that value
    one = m.histogram("lat", stream="s1")
    one.observe(7.5)
    assert m.quantiles("lat", stream="s1") == \
        {"p50": 7.5, "p99": 7.5, "p999": 7.5}


def test_metrics_snapshot_stable_schema():
    m = Metrics()
    m.counter("hits", stream="a").inc(3)
    m.histogram("lat").observe(2.0)
    snap = m.snapshot()
    assert snap["counters"] == {"hits{stream=a}": 3}
    s = snap["histograms"]["lat"]
    assert set(s) == {"count", "sum", "min", "max", "p50", "p99", "p999"}
    # empty histogram still renders every key, zeros throughout
    empty = Metrics().histogram("never").summary()
    assert empty == {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                     "p50": 0.0, "p99": 0.0, "p999": 0.0}
    # reporting lookups never mint series
    assert m.get_histogram("absent") is None
    assert m.quantiles("absent") is None


# ---------------------------------------------------------- determinism ----
def _session_run(artifact, passes="all", tracer=None):
    s = RecordingSession.for_profile(WIFI, passes=passes,
                                     cloud=CloudDryrun(jobs=JOBS),
                                     tracer=tracer)
    rec = s.finalize(_copy(artifact))
    return s, rec.manifest["record_session"]


def _traced_session_run(artifact, passes="all"):
    tr = Tracer()
    _, rep = _session_run(artifact, passes=passes, tracer=tr)
    return tr, rep


def test_trace_determinism_byte_identical(artifact):
    """ISSUE-7 acceptance: same workload recorded twice -> byte-identical
    virtual-time traces once wall timestamps are stripped."""
    tr1, rep1 = _traced_session_run(artifact)
    tr2, rep2 = _traced_session_run(artifact)
    assert rep1 == rep2
    j1 = tr1.to_json(strip_wall=True)
    j2 = tr2.to_json(strip_wall=True)
    assert j1 == j2
    # the wall-bearing exports differ structurally only in wall args
    assert len(tr1.events) == len(tr2.events) > 0


def test_replay_trace_determinism(artifact):
    traces = []
    for _ in range(2):
        tr = Tracer()
        plan = plan_for(_copy(artifact), "all", jobs=JOBS)
        PlanExecutor(netem=NetworkEmulator(WIFI), tracer=tr).run(plan)
        traces.append(tr.to_json(strip_wall=True))
    assert traces[0] == traces[1]
    assert '"replay.dispatch"' in traces[0]
    assert '"replay.collapsed_poll"' in traces[0]


def test_tracing_off_leaves_all_counters_unchanged(artifact):
    """Zero-cost-when-off: every netem/session counter is bit-identical
    between a traced run and an untraced run of the same workload."""
    on, traced_rep = _session_run(artifact, tracer=Tracer())
    off, off_rep = _session_run(artifact)
    assert off.tracer is NULL
    assert off_rep == traced_rep
    assert off.netem.snapshot() == on.netem.snapshot()
    # replay side: traced and untraced executors bill identically
    reports = []
    for tr in (Tracer(), None):
        plan = plan_for(_copy(artifact), "all", jobs=JOBS)
        reports.append(
            PlanExecutor(netem=NetworkEmulator(WIFI), tracer=tr).run(plan))
    assert reports[0] == reports[1]


def test_wifi_record_attribution_ge_95pct(artifact):
    """>= 95% of the session's billed virtual time is covered by named
    record-track spans (the recording-ablation acceptance bar)."""
    for passes in ("none", "all"):
        tr, rep = _traced_session_run(artifact, passes=passes)
        att = tr.attributed_s("record")
        assert rep["virtual_time_s"] > 0
        assert att / rep["virtual_time_s"] >= 0.95


# ------------------------------------------------------------- schema ------
def test_workspace_report_passes_schema_check():
    from repro.api import Workspace
    ws = Workspace(registry=":memory:", key=b"obs-test-key", net="wifi",
                   trace=True)
    wl = ws.workload("cody-mnist", cache_len=32, block_k=4, batch=1, seq=8)
    rec = wl.record("prefill", jobs=8)
    wl.publish(rec)
    wl.fetch("prefill")
    wl.replay(artifact=rec, jobs=8)
    rep = ws.report()
    check_workspace_report(rep)                   # raises on any drift
    assert ws.tracer.events                       # lifecycle left a trace
    # net snapshot carries the once-dropped async/collapsed counters
    assert "async_trips" in rep["net"]
    assert "collapsed_spins" in rep["net"]
    assert rep["net"]["bytes"] == \
        rep["net"]["bytes_sent"] + rep["net"]["bytes_received"]


def test_schema_check_rejects_drift():
    from repro.api import Workspace
    ws = Workspace(registry=":memory:", key=b"obs-test-key", net="wifi")
    wl = ws.workload("cody-mnist", cache_len=32, block_k=4, batch=1, seq=8)
    wl.record("prefill", jobs=8)
    rep = ws.report()
    rep["net"].pop("async_trips")                 # the old snapshot() bug
    with pytest.raises(SchemaError):
        check_workspace_report(rep)
    rep2 = ws.report()
    del rep2["metrics"]
    with pytest.raises(SchemaError):
        check_workspace_report(rep2)


def test_scheduler_stats_schema():
    good = {"preemptions": 0, "eviction_unsupported": 0, "live_slots": 0,
            "max_live_slots": None, "stall_limit": 8,
            "streams": {"s0": {"stalled": 0, "stall_hwm": 0,
                               "unevictable": False, "evicted_requests": 0,
                               "admissions_deferred": 0}}}
    check_scheduler_stats(good)
    bad = dict(good, streams={"s0": {"stalled": 0}})
    with pytest.raises(SchemaError):
        check_scheduler_stats(bad)


def test_check_bench_file_validates_trace_artifact(tmp_path):
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("s", "t"):
        clk.t = 1.0
    p = tmp_path / "TRACE_smoke.json"
    tr.dump(str(p))
    check_bench_file(str(p))
    (tmp_path / "TRACE_empty.json").write_text('{"traceEvents": []}')
    with pytest.raises(SchemaError):
        check_bench_file(str(tmp_path / "TRACE_empty.json"))
    (tmp_path / "BENCH_unknown.json").write_text("{}")
    with pytest.raises(SchemaError):
        check_bench_file(str(tmp_path / "BENCH_unknown.json"))
