"""Engine-level reproduction of the paper's round-trip economics: fused
blocks (deferral) + speculative continuation cut BLOCKING round trips while
producing identical outputs."""
import jax
import numpy as np

from repro.configs import get_config, smoke_shrink
from repro.core.netem import WIFI, NetworkEmulator
from repro.launch.serve import build_engine
from repro.models import model as M


def _run(speculate: bool, block_k: int):
    cfg = smoke_shrink(get_config("qwen2.5-3b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    net = NetworkEmulator(WIFI)
    eng = build_engine(cfg, n_slots=2, cache_len=96, block_k=block_k,
                       eos_id=2, params=params, netem=net,
                       speculate=speculate)
    rng = np.random.default_rng(1)
    for _ in range(4):
        eng.submit(list(rng.integers(3, cfg.vocab_size, 8)), max_new=16)
    outs = eng.run()
    return outs, net, eng


def test_speculation_reduces_blocking_round_trips():
    outs_sync, net_sync, _ = _run(speculate=False, block_k=4)
    outs_spec, net_spec, eng = _run(speculate=True, block_k=4)
    assert outs_sync == outs_spec                       # identical tokens
    assert net_spec.round_trips < net_sync.round_trips  # fewer blocking RTs
    assert net_spec.async_trips > 0                     # hidden commits
    assert net_spec.virtual_time_s < net_sync.virtual_time_s


def test_larger_blocks_fewer_dispatches():
    """Deferral k-step fusion: device dispatches scale ~1/k (paper §4.1)."""
    _, _, e2 = _run(speculate=False, block_k=2)
    _, _, e8 = _run(speculate=False, block_k=8)
    assert e8.stats["blocks_dispatched"] < e2.stats["blocks_dispatched"]
