"""Fault tolerance: checkpoint/restore, elastic re-mesh, straggler monitor,
gradient compression, data pipeline determinism, serving engine e2e."""
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_shrink
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models import model as M
from repro.runtime.checkpoint import CheckpointStore
from repro.runtime.straggler import DispatchMonitor
from repro.training.grad_compress import make_ef_int8_transform
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training import steps as ST

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------------------------ checkpoint ----
def test_checkpoint_roundtrip_and_dedup():
    cfg = smoke_shrink(get_config("qwen2.5-3b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(state, step=1, extra_meta={"cursor_step": 5})
        w1 = store.stats["chunks_written"]
        # unchanged state re-saved: all chunks dedup
        store.save(state, step=2)
        assert store.stats["chunks_written"] == w1
        assert store.stats["chunks_deduped"] >= w1
        restored, manifest = store.restore(state)
        assert manifest["extra"].get("cursor_step", 5) == 5 or \
            manifest["step"] == 2
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # gc keeps the latest
        store.gc(keep_last=1)
        assert store.latest_step() == 2
        store.restore(state, step=2)


def test_checkpoint_async_save():
    cfg = smoke_shrink(get_config("xlstm-350m"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        t = store.async_save({"params": params}, step=3)
        store.wait()
        assert store.latest_step() == 3


def test_train_resume_equals_continuous():
    """Fault-tolerance invariant: crash+restore at step k gives the same
    final state as an uninterrupted run (data cursor included)."""
    cfg = smoke_shrink(get_config("qwen2.5-3b"), num_layers=1, d_model=32,
                       d_ff=64, vocab_size=64)
    opt = AdamWConfig(warmup_steps=2, decay_steps=8)
    step_fn = jax.jit(ST.make_train_step(cfg, None, opt, remat="none"))

    def run(n_steps, state=None, data=None):
        data = data or SyntheticLM(cfg.vocab_size, 2, 16)
        if state is None:
            state = init_opt_state(M.init_params(cfg, jax.random.PRNGKey(0)))
        for _ in range(n_steps):
            batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            state, metrics = step_fn(state, batch)
        return state, data, metrics

    # continuous 6 steps
    s_cont, _, m_cont = run(6)
    # 3 steps -> checkpoint -> restore -> 3 more
    s3, data3, _ = run(3)
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(s3, step=3, extra_meta=data3.meta())
        restored, manifest = store.restore(s3)
        data_r = SyntheticLM(cfg.vocab_size, 2, 16)
        data_r.restore(manifest["extra"])
        s_res, _, m_res = run(3, state=jax.tree.map(jnp.asarray, restored),
                              data=data_r)
    for a, b in zip(jax.tree.leaves(s_cont), jax.tree.leaves(s_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_elastic_restore_subprocess():
    """Save on 1 device, restore + keep training on 8 devices (new mesh)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, sys.argv[1])
from repro.configs import get_config, smoke_shrink
from repro import compat
from repro.models import model as M
from repro.runtime.checkpoint import CheckpointStore
from repro.runtime.elastic import make_elastic_mesh, reshard_state
from repro.training import steps as ST
from repro.training.optimizer import AdamWConfig
from repro.sharding import rules_for
cfg = smoke_shrink(get_config("qwen2.5-3b"), num_layers=1, d_model=32,
                   d_ff=64, vocab_size=64)
store = CheckpointStore(sys.argv[2])
state_np, manifest = store.restore(ST.abstract_train_state(cfg))
mesh = make_elastic_mesh(prefer_model=2)   # 4x2 mesh on 8 devices
state = reshard_state(state_np, ST.train_state_axes(cfg), mesh)
rules = rules_for("train", mesh.axis_names)
step_fn = ST.make_train_step(cfg, rules, AdamWConfig(warmup_steps=1, decay_steps=4))
batch = {"tokens": jnp.ones((4, 16), jnp.int32),
         "labels": jnp.ones((4, 16), jnp.int32)}
with compat.set_mesh(mesh):
    state, metrics = jax.jit(step_fn, donate_argnums=(0,))(state, batch)
assert np.isfinite(float(metrics["loss"]))
print("ELASTIC_OK", float(metrics["loss"]))
"""
    cfg = smoke_shrink(get_config("qwen2.5-3b"), num_layers=1, d_model=32,
                       d_ff=64, vocab_size=64)
    state = init_opt_state(M.init_params(cfg, jax.random.PRNGKey(0)))
    with tempfile.TemporaryDirectory() as d:
        CheckpointStore(d).save(state, step=1)
        out = subprocess.run([sys.executable, "-c", code, SRC, d],
                             capture_output=True, text=True, timeout=300)
        assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


# ------------------------------------------------------------- straggler ----
def test_straggler_monitor_flags_outliers():
    mon = DispatchMonitor(factor=3.0, min_samples=3)
    for _ in range(10):
        assert not mon.observe("s0", 0.010)
    assert mon.observe("s0", 0.500)          # 50x the EWMA
    assert mon.flagged["s0"] == 1
    backup_called = []
    mon2 = DispatchMonitor(factor=2.0, min_samples=1)
    mon2.observe("s1", 0.001)
    mon2.observe("s1", 0.001)
    out = mon2.timed("s1", lambda: time.sleep(0.05) or "slow",
                     backup=lambda: backup_called.append(1) or "backup")
    assert out == "backup" and backup_called


# ------------------------------------------------------- grad compression ----
def test_ef_int8_grad_transform_preserves_training():
    """Error feedback: compressed updates accumulate the quantization
    residual, so the averaged update converges to the true gradient."""
    tf = make_ef_int8_transform()
    g = {"w": jnp.full((128,), 0.001, jnp.float32)}
    state = {}
    total = jnp.zeros((128,))
    for _ in range(64):
        dg, state = tf(g, state)
        total = total + dg["w"]
    np.testing.assert_allclose(total / 64, g["w"], rtol=0.05)


def test_compressed_psum_subprocess():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, sys.argv[1])
from repro.training.grad_compress import compressed_psum
from repro import compat
mesh = compat.make_mesh((8,), ("data",))
x = jnp.linspace(-1.0, 1.0, 4096).reshape(64, 64)
with compat.set_mesh(mesh):
    got = compressed_psum(x, mesh, "data")
want = x * 8
err = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
assert err < 0.03, err
print("PSUM_OK", err)
"""
    out = subprocess.run([sys.executable, "-c", code, SRC],
                         capture_output=True, text=True, timeout=300)
    assert "PSUM_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------- data ----
def test_data_cursor_determinism():
    d1 = SyntheticLM(100, 2, 8, seed=3)
    b1 = [d1.next_batch() for _ in range(3)]
    d2 = SyntheticLM(100, 2, 8, seed=3)
    d2.restore({"cursor_step": 1, "cursor_seed": 3})
    b2 = d2.next_batch()
    np.testing.assert_array_equal(b1[1]["tokens"], b2["tokens"])


def test_prefetcher_steal():
    d = SyntheticLM(100, 2, 8)
    pf = Prefetcher(d, depth=2)
    b = pf.next_batch()
    assert b["tokens"].shape == (2, 8)
    time.sleep(0.05)
    stolen = pf.steal()
    assert stolen is None or stolen["tokens"].shape == (2, 8)
    pf.close()


# ------------------------------------------------------------- serving ----
def test_engine_speculative_matches_sequential():
    """Speculative continuation must produce exactly the tokens the
    non-speculative engine produces (rollback correctness end-to-end)."""
    from repro.launch.serve import main as serve_main
    outs_spec, eng_spec = serve_main(["--arch", "qwen2.5-3b", "--requests",
                                      "5", "--max-new", "12"])
    outs_sync, eng_sync = serve_main(["--arch", "qwen2.5-3b", "--requests",
                                      "5", "--max-new", "12",
                                      "--no-speculate"])
    assert outs_spec == outs_sync
    assert eng_spec.stats["spec_blocks"] >= 0  # speculation may engage
    for r in outs_spec.values():
        assert 0 < len(r) <= 12
