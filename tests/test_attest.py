"""repro.attest: transparency log, epoch key schedule, replay quotes.

Covers the three attestation halves plus their trust boundaries:
  * Merkle log: inclusion/consistency proofs verify exhaustively and
    reject perturbation (RFC 9162 algorithms);
  * key schedule: rotation keeps history verifiable, future epochs are a
    typed protocol violation, stale epoch credentials fail loudly;
  * end-to-end: a silently swapped (validly signed!) recording raises
    ``SplitViewError`` BEFORE any ``pickle.loads``; quotes verify offline
    and reject every bound-field perturbation.
"""
import pickle

import numpy as np
import pytest

from repro.api import Workspace
from repro.attest import (KeySchedule, TransparencyLog, build_quote,
                          leaf_data, proof_wire_bytes, verify_consistency,
                          verify_inclusion, verify_quote)
from repro.attest.quote import BOUND_FIELDS, quote_signable
from repro.attest.verifier import head_signable
from repro.core.attest import (AttestationError, FutureEpochError,
                               QuoteVerificationError, RotatedKeyError,
                               SplitViewError, TamperedRecordingError,
                               canonical, fingerprint)
from repro.core.recording import Recording
from repro.registry.service import recording_to_parts

KEY = b"attest-test-key"


def synthetic_recording(payload_bytes: int = 50_000, seed: int = 0,
                        trees: bytes = None, name: str = "synthetic",
                        sign: bytes = KEY) -> Recording:
    rng = np.random.default_rng(seed)
    payload = rng.bytes(payload_bytes)
    manifest = {"name": name, "static": {}, "record_wall_s": 2.0,
                "exec_fingerprint": fingerprint(payload)}
    rec = Recording(manifest, payload,
                    trees if trees is not None else pickle.dumps((None,
                                                                  None)))
    return rec.sign_with(sign) if sign else rec


# ---------------------------------------------------------- merkle log ----
def test_log_inclusion_proofs_exhaustive():
    """Every (leaf, size) pair up to n=17 verifies; any perturbed path
    element or wrong index fails."""
    log = TransparencyLog()
    for i in range(17):
        assert log.append(b"leaf-%d" % i) == i
    for n in range(1, 18):
        root = log.root(n)
        for i in range(n):
            path = log.inclusion_proof(i, n)
            assert verify_inclusion(b"leaf-%d" % i, i, n, path, root)
            assert not verify_inclusion(b"other", i, n, path, root)
            if path:
                bad = ["0" * 64] + path[1:]
                assert not verify_inclusion(b"leaf-%d" % i, i, n, bad, root)
    assert not verify_inclusion(b"leaf-0", 5, 3,
                                log.inclusion_proof(0, 3), log.root(3))


def test_log_consistency_proofs_exhaustive():
    log = TransparencyLog()
    for i in range(17):
        log.append(b"leaf-%d" % i)
    for old in range(1, 18):
        for new in range(old, 18):
            proof = log.consistency_proof(old, new)
            assert verify_consistency(old, log.root(old), new,
                                      log.root(new), proof)
    # a forked tree: same sizes, different content -> proof rejects
    fork = TransparencyLog()
    for i in range(17):
        fork.append(b"FORK-%d" % i)
    assert not verify_consistency(8, log.root(8), 17, fork.root(17),
                                  fork.consistency_proof(8, 17))


def test_log_proof_size_is_logarithmic():
    log = TransparencyLog()
    for i in range(64):
        log.append(b"e%d" % i)
    assert len(log.inclusion_proof(31, 64)) == 6          # == log2(64)
    assert proof_wire_bytes(log.inclusion_proof(31, 64)) == 6 * 32 + 112
    assert log.root() == log.root(64)
    with pytest.raises(AttestationError):
        log.inclusion_proof(64, 64)
    with pytest.raises(AttestationError):
        log.root(65)


def test_empty_log_root_is_defined():
    assert TransparencyLog().root() == TransparencyLog.EMPTY_ROOT


# -------------------------------------------------------- key schedule ----
def test_key_schedule_shared_root_agrees_and_ratchets():
    a, b = KeySchedule(KEY), KeySchedule(KEY)
    sig0 = a.sign(b"payload")
    assert sig0.startswith("0:") and b.verify(b"payload", sig0)
    assert a.rotate() == 1 and a.epoch == 1
    # epoch-0 signature STILL verifies after rotation (history is kept)
    assert a.verify(b"payload", sig0)
    sig1 = a.sign(b"payload")
    assert sig1.startswith("1:") and sig1 != sig0
    # ...but the epoch-1 signature is a future epoch for the unrotated
    # peer: typed protocol violation, not a quiet False
    with pytest.raises(FutureEpochError):
        b.verify(b"payload", sig1)
    b.rotate()
    assert b.verify(b"payload", sig1)
    assert not b.verify(b"payload", "1:" + "0" * 64)   # wrong mac
    assert not b.verify(b"payload", "garbage")         # malformed -> False


def test_workspace_refuses_rotated_away_epoch_key():
    sched = KeySchedule(KEY)
    old = sched.current()
    sched.rotate()
    assert old.stale
    with pytest.raises(RotatedKeyError):
        Workspace(registry=":memory:", key=old)
    # the CURRENT epoch credential and the schedule itself both work
    ws = Workspace(registry=":memory:", key=sched.current())
    assert ws.keys is sched and ws.keys.epoch == 1
    assert Workspace(registry=":memory:", key=sched).keys is sched


# ------------------------------------------------- strict fingerprints ----
def test_fingerprint_rejects_unfingerprintable_types():
    """Satellite: the canonical encoder must never fall back to str() —
    two distinct objects with identical str() would collide silently."""
    class Sneaky:
        def __init__(self, secret):
            self.secret = secret

        def __str__(self):
            return "same"
    with pytest.raises(TypeError):
        fingerprint(Sneaky(1))
    with pytest.raises(TypeError):
        fingerprint({"nested": {"deep": object()}})
    with pytest.raises(TypeError):
        canonical({1, 2, 3})                            # sets are unordered


def test_fingerprint_byte_compat_for_json_clean_values():
    """The strict encoder is byte-identical to the old json.dumps path
    for JSON-clean values — published registry keys must not drift."""
    import hashlib
    import json
    parts = ({"kind": "decode", "batch": 4}, "mesh-fp", [1, 2], None, True)
    h = hashlib.sha256()
    for p in parts:
        h.update(json.dumps(p, sort_keys=True).encode())
    assert fingerprint(*parts) == h.hexdigest()
    assert fingerprint(b"raw-bytes") == \
        hashlib.sha256(b"raw-bytes").hexdigest()


# ------------------------------------------------- service + log wiring ---
def test_publish_appends_leaf_and_serves_verifiable_proofs():
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    stats = [ws.service.publish(f"k/{i}", synthetic_recording(4_000, seed=i))
             for i in range(5)]
    assert [s["log_index"] for s in stats] == list(range(5))
    assert stats[-1]["log_size"] == 5
    bundle = ws.service.proof_for("k/2")
    head = bundle["head"]
    assert ws.keys.verify(head_signable(head), head["signature"])
    leaf = bundle["leaf"]
    assert leaf["key"] == "k/2"
    assert verify_inclusion(
        leaf_data(leaf["key"], leaf["manifest_fp"], leaf["payload_digest"],
                  leaf["epoch"]),
        bundle["index"], head["size"], bundle["path"], head["root"])


def test_client_pins_head_and_verifies_across_growth():
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    ws.service.publish("a", synthetic_recording(seed=1))
    cl = ws.new_client(netem=ws.fresh_netem())
    cl.fetch("a")
    assert cl.stats["proofs_verified"] == 1 and cl._sth["size"] == 1
    ws.service.publish("b", synthetic_recording(seed=2))   # log grows
    cl.fetch("b")                   # consistency 1 -> 2 verified
    assert cl.stats["proofs_verified"] == 2 and cl._sth["size"] == 2
    assert ws.service.stats["consistency_proofs_served"] == 1
    rep = ws.report()["attest"]
    assert rep["log_size"] == 2 and rep["epoch"] == 0


def test_unrotated_client_rejects_future_epoch_head():
    """A service signing at epoch 1 serves a head a stale epoch-0 client
    cannot verify — that MUST surface as a split-view error, not a quiet
    acceptance of an unverifiable head."""
    from repro.registry.client import RegistryClient
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    ws.rotate_epoch()
    ws.service.publish("k", synthetic_recording())
    stale = RegistryClient(ws.service, netem=ws.fresh_netem(), key=KEY,
                           keys=KeySchedule(KEY))    # fresh: epoch 0
    with pytest.raises(SplitViewError):
        stale.fetch("k")


# ------------------------------------------------------ trust boundary ----
SIDE_EFFECTS = []


class _Evil:
    def __reduce__(self):
        return (SIDE_EFFECTS.append, ("pwned",))


def test_split_view_detected_before_unpickle():
    """THE attack the log exists for: the registry swaps a published
    recording for a different one carrying a VALID signature (so the
    HMAC check alone would admit it into ``pickle.loads``).  The
    transparency leaf disagrees -> typed SplitViewError, zero unpickles."""
    SIDE_EFFECTS.clear()
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    ws.service.publish("victim", synthetic_recording(seed=1))
    old_meta = ws.store.entry("victim")["meta"]
    evil = synthetic_recording(seed=2, name="evil",
                               trees=pickle.dumps(_Evil()))
    ws.store.put("victim", recording_to_parts(evil, ws.store.chunk_size),
                 meta=old_meta)
    with pytest.raises(SplitViewError):
        ws.client.fetch("victim")
    assert SIDE_EFFECTS == []


def test_tamper_matrix_over_variant_lease_publishes():
    """Satellite: publish through ``VariantLeaseSet.complete`` (the
    campaign's incremental-publish path), then swap in a mutant of each
    recording part.  Every mutation is rejected with a typed error
    BEFORE any unpickle: signature-breaking mutants die at the HMAC,
    validly re-signed mutants die at the transparency leaf."""
    SIDE_EFFECTS.clear()
    good = synthetic_recording(seed=7)
    evil_trees = pickle.dumps(_Evil())

    def mutants():
        m = dict(good.manifest, static={"swapped": True})
        yield "manifest", Recording(m, good.payload,
                                    good.trees).sign_with(KEY)
        p = bytes(good.payload[:-1]) + b"\x00"
        yield "payload", Recording(dict(good.manifest,
                                        exec_fingerprint=fingerprint(p)),
                                   p, evil_trees).sign_with(KEY)
        yield "trees", Recording(dict(good.manifest), good.payload,
                                 evil_trees, good.signature)  # not re-signed
        yield "signature", Recording(dict(good.manifest), good.payload,
                                     evil_trees, "0:" + "ab" * 32)

    for part, mutant in mutants():
        ws = Workspace(registry=":memory:", key=KEY, net="wifi")
        lease = ws.service.variant_lease("campaign", ["k"])
        assert lease.claim("k") is None
        out = lease.complete("k", synthetic_recording(seed=7))
        assert out["log_index"] == 0 and out["log_size"] == 1
        old_meta = ws.store.entry("k")["meta"]
        ws.store.put("k", recording_to_parts(mutant, ws.store.chunk_size),
                     meta=old_meta)
        # SplitViewError IS a TamperedRecordingError: one catch-site
        with pytest.raises(TamperedRecordingError):
            ws.client.fetch("k")
        assert SIDE_EFFECTS == [], f"unpickle ran for {part} mutant"


def test_store_entry_without_leaf_is_refused_a_proof():
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    rogue = synthetic_recording(seed=9)
    ws.store.put("rogue", recording_to_parts(rogue, ws.store.chunk_size),
                 meta={"name": "rogue"})        # bypassed publish()
    with pytest.raises(AttestationError):
        ws.service.proof_for("rogue")
    with pytest.raises(AttestationError):   # surfaces through fetch too
        ws.client.fetch("rogue")


def test_replica_relays_proofs_and_detects_regional_fork():
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    ws.service.publish("k", synthetic_recording())
    cl = ws.new_client(netem=ws.fresh_netem(), region="eu")
    cl.fetch("k")
    assert cl.stats["proofs_verified"] == 1
    rr = ws.read_replica("eu")
    assert rr.stats["proofs_relayed"] == 1
    assert "proofs_relayed" in rr.summary()


# -------------------------------------------------------------- quotes ----
def _quoted_replay(ws, reg_key):
    from repro.core.replay_passes import PlanExecutor, verified_plan
    blob = ws.client.fetch(reg_key)
    plan, _rec = verified_plan(blob, KEY, "all", jobs=4)
    ex = PlanExecutor(netem=ws.fresh_netem())
    ex.run(plan)
    return ex.quote(ws.keys, recording_key=reg_key,
                    head=ws.service.signed_head())


def test_quote_roundtrip_offline_and_perturbation_rejection():
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    ws.service.publish("q/prefill", synthetic_recording(seed=3))
    quote = _quoted_replay(ws, "q/prefill")
    head = ws.service.signed_head()
    bundle = ws.service.proof_for("q/prefill")

    offline = KeySchedule(KEY)      # the remote verifier's whole state
    rep = verify_quote(quote, head=head, keys=offline, leaf=bundle["leaf"],
                       proof=bundle["path"], leaf_index=bundle["index"])
    assert rep["ok"] and rep["inclusion_checked"]
    assert rep["recording_key"] == "q/prefill"

    for field in BOUND_FIELDS:
        bad = dict(quote)
        bad[field] = 999 if isinstance(quote[field], int) \
            else quote[field] + "x"
        with pytest.raises(QuoteVerificationError):
            verify_quote(bad, head=head, keys=offline)
    # annotations are NOT bound: editing one leaves the quote valid
    relabeled = dict(quote, passes="forged-annotation")
    assert verify_quote(relabeled, head=head, keys=offline)["ok"]
    # ...but a wrong key schedule is
    with pytest.raises(QuoteVerificationError):
        verify_quote(quote, head=head, keys=KeySchedule(b"other-root"))


def test_quote_survives_epoch_rotation():
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    ws.service.publish("q/prefill", synthetic_recording(seed=4))
    quote = _quoted_replay(ws, "q/prefill")
    head = ws.service.signed_head()
    assert ws.rotate_epoch() == 1
    verifier = KeySchedule(KEY)
    verifier.rotate()
    assert verify_quote(quote, head=head, keys=verifier)["ok"]
    assert quote["epoch"] == 0      # quoted in the epoch it ran under


def test_quote_signable_requires_bound_fields():
    with pytest.raises(ValueError):
        quote_signable({"recording_key": "k"})
    sched = KeySchedule(KEY)
    head = {"size": 0, "root": TransparencyLog.EMPTY_ROOT, "epoch": 0,
            "signature": sched.sign(head_signable(
                {"size": 0, "root": TransparencyLog.EMPTY_ROOT}))}
    q = build_quote(sched, recording_key="k", exec_fingerprint="e",
                    plan_fingerprint="p", frontier_digest="f", head=head,
                    annotations={"signature": "cannot-shadow", "extra": 1})
    assert q["extra"] == 1 and q["signature"] != "cannot-shadow"
    assert verify_quote(q, head=head, keys=sched)["ok"]


def test_offline_verifier_imports_no_model_or_registry_code():
    import repro.attest.verifier as V
    src = open(V.__file__).read()
    for forbidden in ("repro.models", "repro.configs", "repro.training",
                      "repro.serving", "repro.registry", "repro.record",
                      "jax"):
        assert f"import {forbidden}" not in src
        assert f"from {forbidden}" not in src


# -------------------------------------------------------------- schema ----
def test_workspace_report_attest_section_validates():
    from repro.obs.schema import SchemaError, check_workspace_report
    ws = Workspace(registry=":memory:", key=KEY, net="wifi")
    ws.service.publish("k", synthetic_recording())
    ws.client.fetch("k")
    rep = check_workspace_report(ws.report())
    assert rep["attest"]["proofs_verified"] == 1
    assert rep["attest"]["proof_bytes"] > 0
    broken = dict(rep, attest={"epoch": 0})
    with pytest.raises(SchemaError):
        check_workspace_report(broken)


def test_bench_attest_schema_flags():
    from repro.obs.schema import BENCH_CHECKS, SchemaError
    check = BENCH_CHECKS["BENCH_attest.json"]
    good = {
        "proof_ladder": [{"entries": n, "proof_hashes": 1,
                          "proof_wire_bytes": 144, "log2_bound": 6}
                         for n in (1, 2, 4)],
        "verify_overhead": {"warm_fetch_unverified_s": 1.0,
                            "warm_fetch_verified_s": 1.01,
                            "overhead_pct": 1.0, "proof_bytes": 112},
        "split_view": {"detected": True},
        "quote": {"bound_fields": list(BOUND_FIELDS),
                  "perturbations_rejected": list(BOUND_FIELDS)},
        "split_view_detected": True, "verify_overhead_le_5pct": True,
        "offline_verifier_no_model_imports": True,
        "proof_growth_sublinear": True,
    }
    check(good)
    with pytest.raises(SchemaError):
        check(dict(good, split_view_detected=False))
    with pytest.raises(SchemaError):
        check({k: v for k, v in good.items() if k != "quote"})
