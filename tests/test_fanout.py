"""Multi-device record fan-out: RecordCampaign scheduling, shared
per-hardware-class speculation history, multi-variant lease fan-out,
per-device netem span isolation, and the SessionReusedError satellite."""
import threading

import jax
import jax.numpy as jnp
import pytest

from repro.api import Workspace
from repro.core.netem import WIFI, NetworkEmulator
from repro.core.recorder import compile_artifact
from repro.core.recording import Recording
from repro.core.speculation import HistorySpeculator
from repro.record import (CloudDryrun, DeviceProxy, DeviceSlot,
                          RecordCampaign, RecordingSession,
                          SessionReusedError, VariantSpec)
from repro.registry.store import RegistryMissError

KEY = b"fanout-test-key"
SHAPES = dict(cache_len=32, block_k=4, batch=2, prefill_batch=1, seq=8)


def _tiny():
    return (lambda x: jnp.tanh(x) * 2.0,
            (jax.ShapeDtypeStruct((8,), jnp.float32),))


@pytest.fixture(scope="module")
def artifact():
    fn, spec = _tiny()
    return compile_artifact("t", fn, spec)


def _copy(rec):
    return Recording(dict(rec.manifest), rec.payload, rec.trees)


def _ws(**kw):
    return Workspace(registry=":memory:", key=KEY, net="wifi", **kw)


def _campaign(ws, *, devices=2, seqs=(8, 16), **kw):
    wl = ws.workload("cody-mnist", **SHAPES)
    items = wl.variants(seqs=list(seqs), kinds=("prefill", "decode"))
    return ws.campaign(items, devices=devices, jobs=6, **kw)


# ------------------------------------------------ SessionReusedError ----
def test_session_reuse_raises_dedicated_error(artifact):
    """Second exercise() raises SessionReusedError naming the call site
    that consumed the session first (still a RuntimeError carrying
    "single-use", so existing handlers keep working)."""
    session = RecordingSession.for_profile(WIFI)
    session.finalize(_copy(artifact))          # first (legitimate) use
    with pytest.raises(SessionReusedError, match="single-use") as ei:
        session.exercise(_copy(artifact))
    assert isinstance(ei.value, RuntimeError)
    # the offending FIRST-use site is this test file, recorded at the
    # finalize() call above
    assert "test_fanout.py" in ei.value.first_use_site
    assert ei.value.first_use_site in str(ei.value)


# ------------------------------------------- shared speculation history ----
def test_injected_speculator_warms_across_sessions(artifact):
    """Device B's session starts with device A's validated history: same
    work, strictly fewer blocking round trips, and the lift shows up in
    the speculator's own predict/hit counters."""
    def run(spec):
        s = RecordingSession(device=DeviceProxy(), cloud=CloudDryrun(jobs=6),
                             netem=NetworkEmulator(WIFI), speculator=spec)
        s.finalize(_copy(artifact))
        return s.report()

    cold_a = run(None)                         # private speculator each
    cold_b = run(None)
    assert cold_a["blocking_round_trips"] == cold_b["blocking_round_trips"]

    shared = HistorySpeculator(k=3)
    run(shared)
    hits_after_first = int(shared.stats["predicted"])
    warm = run(shared)                         # second device, same history
    assert warm["blocking_round_trips"] < cold_b["blocking_round_trips"]
    assert warm["virtual_time_s"] < cold_b["virtual_time_s"]
    assert int(shared.stats["predicted"]) > hits_after_first
    assert shared.stats["predicts"] > 0 and shared.stats["records"] > 0


# ------------------------------------------------------- campaign core ----
def test_campaign_records_all_variants_and_publishes():
    ws = _ws()
    c = _campaign(ws, devices=2)
    recs = c.run()
    s = c.stats()
    assert s["recorded"] == s["variants"] == len(recs) == 3
    assert s["publishes"] == 3
    for key in recs:
        assert ws.service.has(key)             # incrementally published
    assert ws.service.stats["variant_lease_groups"] == 1
    assert ws.service.stats["variant_claims"] == 3
    # fan-out beat the serial sum of its own records
    assert s["virtual_time_s"] < s["sum_record_virtual_s"]
    # report() carries the campaign block and passes the pinned schema
    from repro.obs.schema import check_workspace_report
    rep = check_workspace_report(ws.report())
    assert rep["campaigns"][0]["name"] == s["name"]


def test_campaign_execution_order_is_device_count_invariant():
    """FIFO claiming makes execution order = queue order at every device
    count, so per-variant session costs are identical across the ladder
    and the makespan shrinkage is pure concurrency."""
    arts = {}
    times = {}
    for devices in (1, 2, 4):
        c = _campaign(_ws(), devices=devices, seqs=(8, 16, 24),
                      artifacts=arts, name=f"ladder-d{devices}")
        c.run()
        s = c.stats()
        # same per-variant costs in the same order at every width (to
        # within the report's rounding: different devices' emulators sit
        # at different absolute clock values, so deltas differ in the
        # last ulp)
        order = [k for k, _rep in c.sessions]
        durations = [rep["virtual_time_s"] for _k, rep in c.sessions]
        assert order == times.setdefault("order", order)
        assert durations == pytest.approx(
            times.setdefault("durations", durations), abs=1e-5)
        times[devices] = s["virtual_time_s"]
    assert times[1] > times[2] > times[4]      # strictly monotone


def test_campaign_skips_already_published_variants():
    ws = _ws()
    arts = {}
    _campaign(ws, artifacts=arts, name="first").run()
    c2 = _campaign(ws, artifacts=arts, name="second")
    c2.run()
    s = c2.stats()
    assert s["recorded"] == 0 and s["skipped_published"] == 3
    assert s["virtual_time_s"] == 0.0


def test_campaign_recordings_bit_exact_vs_serial():
    """A fanned-out variant is byte-identical to the same variant recorded
    through today's serial cold-session path (shared artifact, so
    payload/trees/fingerprint must match exactly)."""
    arts = {}
    serial = _campaign(_ws(), devices=1, share_history=False,
                       artifacts=arts, name="serial").run()
    fanned = _campaign(_ws(), devices=2, artifacts=arts,
                       name="fanned").run()
    assert set(serial) == set(fanned)
    for key, rec in fanned.items():
        base = serial[key]
        assert rec.payload == base.payload and rec.trees == base.trees
        assert rec.manifest["exec_fingerprint"] == \
            base.manifest["exec_fingerprint"]


def test_campaign_is_single_run_and_deterministic():
    c = _campaign(_ws(), name="det-a")
    c.run()
    with pytest.raises(RuntimeError, match="already ran"):
        c.run()
    c2 = _campaign(_ws(), name="det-a")
    c2.run()
    a, b = c.stats(), c2.stats()
    assert a == b                              # virtual clock: no wall, no rng


# ------------------------------- per-device billing + netem span aliasing ----
def test_per_device_netem_spans_do_not_alias():
    """Sessions interleave across devices on the campaign tick clock;
    each device's emulator must bill exactly its own sessions' spans
    (checkpoint()/delta() per session, one emulator per device)."""
    ws = _ws()
    c = _campaign(ws, devices=2, seqs=(8, 16, 24))
    c.run()
    assert len({id(d.netem) for d in c.devices}) == 2
    billed = {}
    # device emulator totals == sum of its own sessions (reports carry the
    # per-session checkpoint/delta split; busy_virtual_s accumulates them)
    for d in c.devices:
        # busy_virtual_s sums per-session reports (rounded to 6 decimals
        # each), so allow that rounding to accumulate
        assert d.netem.virtual_time_s == pytest.approx(d.busy_virtual_s,
                                                       abs=1e-5)
        billed[d.name] = d.netem.snapshot()
    # both devices worked, and neither absorbed the other's traffic
    assert all(b["round_trips"] > 0 for b in billed.values())
    total_rts = sum(b["round_trips"] for b in billed.values())
    assert total_rts == sum(rep["blocking_round_trips"]
                            for _k, rep in c.sessions)
    assert sum(d.recorded for d in c.devices) == len(c.sessions)


def test_interleaved_checkpoint_delta_spans_across_devices():
    """The raw netem span API under campaign-style interleaving: spans
    opened on different emulators, advanced in alternation, must each see
    only their own traffic."""
    a, b = NetworkEmulator(WIFI), NetworkEmulator(WIFI)
    ma, mb = a.checkpoint(), b.checkpoint()
    a.round_trip(send_bytes=100, recv_bytes=100)
    b.round_trip(send_bytes=200, recv_bytes=200)
    a.round_trip(send_bytes=100, recv_bytes=100)
    mb2 = b.checkpoint()                       # nested span on b only
    b.async_trip(send_bytes=50, recv_bytes=0)
    a.one_way(1000, direction="recv")          # no round trip billed
    da, db2, db = a.delta(ma), b.delta(mb2), b.delta(mb)
    assert da["round_trips"] == 2 and da["async_trips"] == 0
    assert db["round_trips"] == 1 and db["async_trips"] == 1
    assert db2["round_trips"] == 0 and db2["async_trips"] == 1
    assert da["bytes_sent"] == 200 and da["bytes_received"] == 200 + 1000
    assert db["bytes_sent"] == 250 and db["bytes_received"] == 200
    # virtual time billed on each link is independent of the other's
    assert a.delta(ma)["time_s"] == da["time_s"]
    assert da["time_s"] != db["time_s"]


# ------------------------------------------------ per-device spec metrics ----
def test_per_device_speculation_metrics_counters():
    ws = _ws()
    c = _campaign(ws, devices=2, seqs=(8, 16, 24))
    c.run()
    snap = ws.metrics.snapshot()["counters"]
    for d in c.devices:
        if not d.recorded:
            continue
        for stat in ("predict", "hit", "record"):
            k = (f"spec_history_{stat}{{device={d.name},"
                 f"hw_class=edge-gpu}}")
            assert snap.get(k, 0) == d.stats[f"spec_{stat}"] > 0
    h = ws.metrics.get_histogram("fanout_record_s", campaign=c.name,
                                 device="dev0")
    assert h is not None and h.count == c.devices[0].recorded
    # campaign hit accounting comes from those counters, not RTTs
    s = c.stats()
    assert s["speculation"]["predicts"] == \
        sum(d.stats["spec_predict"] for d in c.devices)
    assert 0.0 < s["speculation"]["hit_rate"] <= 1.0


# -------------------------------------------------- variant lease fan-out ----
def test_variant_lease_claim_complete_and_waiters(artifact):
    ws = _ws()
    svc = ws.service
    rec = _copy(artifact)
    lease = svc.variant_lease("campaign-x", ["k/a", "k/b"])
    assert lease.claim("k/a") is None
    assert lease.claim("k/b") is None
    # a second campaign can't double-claim, a plain misser becomes a waiter
    other = svc.variant_lease("campaign-y", ["k/a"])
    assert other.claim("k/a") == "leased"
    got = []
    t = threading.Thread(target=lambda: got.append(
        svc.ensure("k/a")))      # no record_fn: must ride the lease
    t.start()
    lease.complete("k/a", rec)
    t.join(timeout=5)
    assert not t.is_alive() and len(got) == 1
    assert svc.has("k/a")
    # published keys are skipped, not re-leased
    late = svc.variant_lease("campaign-z", ["k/a"])
    assert late.claim("k/a") == "published"
    # fail() releases without publishing; waiters surface the miss
    lease.fail("k/b")
    assert not svc.has("k/b")
    with pytest.raises(RegistryMissError):
        svc.ensure("k/b")
    assert lease.outstanding() == set()


def test_variant_lease_complete_requires_ownership(artifact):
    ws = _ws()
    lease = ws.service.variant_lease("c", ["k/x"])
    with pytest.raises(KeyError, match="not leased"):
        lease.complete("k/x", _copy(artifact))


def test_campaign_failure_releases_leases():
    """A variant whose compile blows up must not leave its lease (or the
    other claimed variants') stuck — later missers would deadlock."""
    ws = _ws()

    def boom():
        raise RuntimeError("compile exploded")

    v = VariantSpec("broken/key", boom)
    slot = DeviceSlot("dev0", ws.fresh_netem())
    c = RecordCampaign([v], [slot], service=ws.service, jobs=6)
    with pytest.raises(RuntimeError, match="compile exploded"):
        c.run()
    assert "broken/key" not in ws.service._leases
    with pytest.raises(RegistryMissError):
        ws.service.ensure("broken/key")        # miss, not a hang
