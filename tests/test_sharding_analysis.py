"""Sharding resolution + HLO analyzer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro.analysis.hlo import _shape_bytes, _wire_bytes, analyze
from repro.sharding import rules_for, spec


# ------------------------------------------------------------- sharding ----
def test_rules_modes():
    train = rules_for("train", ("pod", "data", "model"))
    assert train["batch"] == ("pod", "data")
    assert train["seq"] == "model"          # Megatron SP
    assert train["fsdp"] == ("pod", "data")
    serve = rules_for("serve", ("data", "model"))
    assert serve["fsdp"] is None            # no weight gathers at decode
    assert serve["kv_seq"] == "model"       # SP cache


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 512), st.integers(1, 512))
def test_spec_divisibility_fallback(d0, d1):
    """Any shape resolves to a legal spec: dims not divisible by the mesh
    axis product fall back to replication."""
    rules = rules_for("train", ("data", "model"))
    mesh_shape = {"data": 16, "model": 16}
    s = spec(("batch", "ffn"), rules, (d0, d1), mesh_shape)
    for dim, part in zip((d0, d1), s):
        if part is not None:
            n = np.prod([mesh_shape[a] for a in
                         (part if isinstance(part, tuple) else (part,))])
            assert dim % n == 0


def test_spec_dedup_physical_axes():
    rules = rules_for("serve", ("data", "model"))
    s = spec(("batch", "kv_seq", "kv_heads", "head_dim"), rules,
             (128, 4096, 8, 128), {"data": 16, "model": 16})
    flat = [a for a in s if a is not None]
    assert len(set(map(str, flat))) == len(flat)  # no axis used twice


# ------------------------------------------------------------- analyzer ----
def test_shape_bytes():
    assert _shape_bytes("bf16[4,32]{1,0}") == 256
    assert _shape_bytes("f32[]") == 4
    assert _shape_bytes("(s32[], bf16[4,32]{1,0})") == 4 + 256
    assert _shape_bytes("pred[10]") == 10


def test_wire_bytes_formulas():
    assert _wire_bytes("all-gather", 0, 1024, 4) == 768     # S(n-1)/n
    assert _wire_bytes("all-reduce", 1024, 1024, 4) == 1536  # 2S(n-1)/n
    assert _wire_bytes("reduce-scatter", 1024, 256, 4) == 768
    assert _wire_bytes("collective-permute", 0, 512, 4) == 512


def test_analyzer_scan_equals_unrolled_flops():
    """Trip-count correction: scan flops == unrolled flops == analytic."""
    L, D, B = 5, 64, 32

    def layer(h, w):
        return jnp.dot(h, w), ()

    def f_scan(ws, x):
        h, _ = jax.lax.scan(layer, x, ws)
        return h.sum()

    def f_unroll(ws, x):
        h = x
        for i in range(L):
            h, _ = layer(h, ws[i])
        return h.sum()

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    a_s = analyze(jax.jit(f_scan).lower(ws, x).compile().as_text())
    a_u = analyze(jax.jit(f_unroll).lower(ws, x).compile().as_text())
    analytic = L * 2 * B * D * D
    assert abs(a_s["flops"] - analytic) / analytic < 0.05
    assert abs(a_u["flops"] - analytic) / analytic < 0.05


def test_analyzer_trip_count_from_condition():
    """Post-SPMD dumps lack backend_config — trip count comes from the loop
    condition constant."""
    text = """
HloModule test

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %w = f32[4,4] constant({...})
  %y = f32[4] dot(%x, %w), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[4]) tuple(%ni, %y)
}

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(9)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[4]) tuple(%zero, %x)
  %wl = (s32[], f32[4]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4] get-tuple-element(%wl), index=1
}
"""
    a = analyze(text, mode="spmd")
    assert a["flops"] == 9 * 2 * 4 * 4  # 9 trips x dot(4x4)


def test_analyzer_collectives_in_loops_multiply():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (covered by subprocess test)")
