"""Render EXPERIMENTS.md tables from dry-run artifacts."""
import glob
import json
import os
import sys


def fmt(v, n=3):
    if v == 0:
        return "0"
    if abs(v) >= 100 or abs(v) < 0.001:
        return f"{v:.2e}"
    return f"{v:.{n}f}"


def dryrun_table(art="artifacts/final", mesh="16x16"):
    rows = []
    for f in sorted(glob.glob(f"{art}/*_{mesh}.json")):
        r = json.load(open(f))
        if r["mesh"] != mesh:
            continue
        rows.append(r)
    out = ["| arch | shape | status | resident GiB/dev | HLO GFLOPs/dev | "
           "coll GiB/dev | compile s |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | **skip** "
                       f"(full attention @500k) | — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{r['resident_bytes']/2**30:.2f} | "
            f"{r['hlo']['flops']/1e9:.0f} | "
            f"{r['hlo']['coll_bytes']/2**30:.2f} | {r['t_compile_s']:.0f} |")
    return "\n".join(out)


def multipod_table(art="artifacts/final"):
    out = ["| arch | shape | 16x16 | 2x16x16 | pod-axis collectives |",
           "|---|---|---|---|---|"]
    cells = {}
    for f in sorted(glob.glob(f"{art}/*.json")):
        r = json.load(open(f))
        cells.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = r
    for (a, s), d in sorted(cells.items()):
        r1, r2 = d.get("16x16"), d.get("2x16x16")
        if not r1 or not r2:
            continue
        if r1["status"] == "skip":
            out.append(f"| {a} | {s} | skip | skip | — |")
            continue
        ok1 = "ok" if r1["status"] == "ok" else "ERR"
        ok2 = "ok" if r2["status"] == "ok" else "ERR"
        pod = "yes" if (r2.get("hlo", {}).get("coll_bytes", 0) > 0) else "-"
        out.append(f"| {a} | {s} | {ok1} | {ok2} | {pod} |")
    return "\n".join(out)


def roofline_table(art="artifacts/final", mesh="16x16"):
    out = ["| arch | shape | T_comp s | T_mem s | T_coll s | dominant | "
           "MODEL/HLO | roofline frac | MFU |",
           "|---|---|---|---|---|---|---|---|---|"]
    rows = []
    for f in sorted(glob.glob(f"{art}/*_{mesh}.json")):
        r = json.load(open(f))
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rows.append(r)
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rf['t_compute_s'])} | "
            f"{fmt(rf['t_memory_s'])} | {fmt(rf['t_collective_s'])} | "
            f"{rf['dominant']} | {rf['model_flops_ratio']:.2f} | "
            f"{rf['roofline_fraction']:.3f} | {rf['mfu']:.3f} |")
    return "\n".join(out)


def opt_compare(base="artifacts/final", opt="artifacts/final_opt"):
    out = ["| arch | shape | variant | step s (base) | step s (opt) | "
           "speedup | dominant (base→opt) |",
           "|---|---|---|---|---|---|---|"]
    for f in sorted(glob.glob(f"{opt}/*_16x16-*.json")):
        r2 = json.load(open(f))
        if r2["status"] != "ok":
            continue
        tag = f.rsplit("-", 1)[1][:-5]
        bf = f"{base}/{r2['arch']}_{r2['shape']}_16x16.json"
        if not os.path.exists(bf):
            continue
        r1 = json.load(open(bf))
        if r1["status"] != "ok":
            continue
        t1 = max(r1["roofline"]["t_compute_s"], r1["roofline"]["t_memory_s"],
                 r1["roofline"]["t_collective_s"])
        t2 = max(r2["roofline"]["t_compute_s"], r2["roofline"]["t_memory_s"],
                 r2["roofline"]["t_collective_s"])
        out.append(
            f"| {r2['arch']} | {r2['shape']} | {tag} | {fmt(t1)} | {fmt(t2)} | "
            f"{t1/t2:.2f}x | {r1['roofline']['dominant']}→"
            f"{r2['roofline']['dominant']} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("### dryrun\n" + dryrun_table())
    if which in ("multipod", "all"):
        print("\n### multipod\n" + multipod_table())
    if which in ("roofline", "all"):
        print("\n### roofline\n" + roofline_table())
    if which in ("opt", "all"):
        print("\n### opt\n" + opt_compare())
